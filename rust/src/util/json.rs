//! Minimal JSON value + serializer (no `serde_json` in the vendored set).
//!
//! Only what the metrics/report paths need: objects, arrays, strings,
//! numbers, booleans, null, with stable (insertion-ordered) object keys
//! and correct string escaping. Writing, not parsing, is the primary use;
//! a small parser is included for round-trip tests and for reading
//! artifact metadata emitted by the Python compile path.

use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Self {
        Json::Obj(Vec::new())
    }

    /// Insert (or replace) a key on an object; panics on non-objects.
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Self {
        match &mut self {
            Json::Obj(entries) => {
                let v = value.into();
                if let Some(e) = entries.iter_mut().find(|(k, _)| k == key) {
                    e.1 = v;
                } else {
                    entries.push((key.to_string(), v));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(entries) => entries.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    // JSON has no Inf/NaN; encode as null like serde_json.
                    out.push_str("null");
                }
            }
            Json::Str(s) => Self::write_escaped(out, s),
            Json::Arr(items) => {
                Self::write_seq(out, indent, depth, '[', ']', items.len(), |out, i| {
                    items[i].write(out, indent, depth + 1)
                });
            }
            Json::Obj(entries) => {
                Self::write_seq(out, indent, depth, '{', '}', entries.len(), |out, i| {
                    Self::write_escaped(out, &entries[i].0);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    entries[i].1.write(out, indent, depth + 1)
                });
            }
        }
    }

    fn write_seq(
        out: &mut String,
        indent: Option<usize>,
        depth: usize,
        open: char,
        close: char,
        len: usize,
        mut item: impl FnMut(&mut String, usize),
    ) {
        out.push(open);
        for i in 0..len {
            if i > 0 {
                out.push(',');
            }
            if let Some(w) = indent {
                out.push('\n');
                out.push_str(&" ".repeat(w * (depth + 1)));
            }
            item(out, i);
        }
        if len > 0 {
            if let Some(w) = indent {
                out.push('\n');
                out.push_str(&" ".repeat(w * depth));
            }
        }
        out.push(close);
    }

    fn write_escaped(out: &mut String, s: &str) {
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\r' => out.push_str("\\r"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => {
                    let _ = write!(out, "\\u{:04x}", c as u32);
                }
                c => out.push(c),
            }
        }
        out.push('"');
    }

    /// Parse a JSON document (strict enough for our own output and the
    /// Python-side artifact manifests).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u8> for Json {
    fn from(v: u8) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}
impl From<&[f64]> for Json {
    fn from(v: &[f64]) -> Self {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut entries = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(entries));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            entries.push((key, self.value()?));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(entries));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_serialize() {
        let j = Json::obj()
            .set("model", "ddpm")
            .set("gops", 1234.5)
            .set("ok", true)
            .set("tags", Json::Arr(vec!["a".into(), "b".into()]));
        let s = j.to_string_compact();
        assert_eq!(
            s,
            r#"{"model":"ddpm","gops":1234.5,"ok":true,"tags":["a","b"]}"#
        );
    }

    #[test]
    fn round_trip() {
        let j = Json::obj()
            .set("x", 1.0)
            .set("s", "he\"llo\n")
            .set("null", Json::Null)
            .set("nest", Json::obj().set("arr", Json::Arr(vec![Json::Num(1.5)])));
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed, j);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(42.25).to_string_compact(), "42.25");
    }

    #[test]
    fn escapes() {
        let j = Json::Str("tab\there".into());
        assert_eq!(j.to_string_compact(), r#""tab\there""#);
        assert_eq!(Json::parse(r#""tab\there""#).unwrap(), j);
    }

    #[test]
    fn parse_rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn set_replaces_existing_key() {
        let j = Json::obj().set("k", 1.0).set("k", 2.0);
        assert_eq!(j.get("k").and_then(Json::as_f64), Some(2.0));
    }

    #[test]
    fn nonfinite_encodes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }
}
