//! Shard router: assigns incoming generation requests to fleet devices.
//!
//! Three policies:
//!
//! * [`ShardPolicy::RoundRobin`] — rotate through non-full devices.
//! * [`ShardPolicy::LeastLoaded`] — lowest resident+queued occupancy,
//!   ties broken by device id (deterministic).
//! * [`ShardPolicy::Affinity`] — hash the request's sampler signature to
//!   a home device so same-signature requests co-locate (keeps each
//!   device's compiled-executable cache and timestep stride hot), with
//!   least-loaded fallback when the home device is full.
//!
//! Admission control: a device is *full* when `resident + queued` reaches
//! `capacity + max_queue`; when every device is full the router returns
//! `None` and the caller must shed the request (backpressure).

use crate::coordinator::request::SamplerKind;

use super::device::DeviceId;

/// Routing policy for sharding requests across devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShardPolicy {
    RoundRobin,
    #[default]
    LeastLoaded,
    /// Sampler-signature affinity with least-loaded fallback.
    Affinity,
}

impl ShardPolicy {
    /// Parse a CLI spelling; `None` for unknown values.
    pub fn parse(s: &str) -> Option<ShardPolicy> {
        match s {
            "round-robin" | "rr" => Some(ShardPolicy::RoundRobin),
            "least-loaded" | "ll" => Some(ShardPolicy::LeastLoaded),
            "affinity" => Some(ShardPolicy::Affinity),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ShardPolicy::RoundRobin => "round-robin",
            ShardPolicy::LeastLoaded => "least-loaded",
            ShardPolicy::Affinity => "affinity",
        }
    }
}

/// Occupancy snapshot of one device, as the router sees it.
#[derive(Debug, Clone, Copy)]
pub struct DeviceLoad {
    pub resident: usize,
    pub queued: usize,
    pub capacity: usize,
    pub max_queue: usize,
}

impl DeviceLoad {
    pub fn total(&self) -> usize {
        self.resident + self.queued
    }

    pub fn is_full(&self) -> bool {
        self.total() >= self.capacity + self.max_queue
    }
}

/// Stable 64-bit signature of a sampler setting (affinity key).
pub fn sampler_signature(sampler: SamplerKind) -> u64 {
    // splitmix64 finalizer over a small discriminant+payload encoding.
    let raw = match sampler {
        SamplerKind::Ddpm => 1u64 << 32,
        SamplerKind::Ddim { steps } => (2u64 << 32) | steps as u64,
    };
    let mut z = raw.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The shard router. Stateful only for round-robin rotation.
#[derive(Debug, Clone)]
pub struct Router {
    policy: ShardPolicy,
    rr_next: usize,
}

impl Router {
    pub fn new(policy: ShardPolicy) -> Self {
        Self { policy, rr_next: 0 }
    }

    pub fn policy(&self) -> ShardPolicy {
        self.policy
    }

    /// Pick a device for a request, or `None` when every device is full.
    pub fn route(&mut self, sampler: SamplerKind, loads: &[DeviceLoad]) -> Option<DeviceId> {
        if loads.is_empty() || loads.iter().all(DeviceLoad::is_full) {
            return None;
        }
        let pick = match self.policy {
            ShardPolicy::RoundRobin => {
                let n = loads.len();
                let mut chosen = None;
                for off in 0..n {
                    let i = (self.rr_next + off) % n;
                    if !loads[i].is_full() {
                        chosen = Some(i);
                        self.rr_next = (i + 1) % n;
                        break;
                    }
                }
                chosen?
            }
            ShardPolicy::LeastLoaded => least_loaded(loads)?,
            ShardPolicy::Affinity => {
                // Stay home while the home device has free batch slots;
                // once it is saturated (resident + queued at capacity),
                // spill to the least-loaded device — otherwise a
                // homogeneous workload would serialize the whole fleet
                // onto one device.
                let home = (sampler_signature(sampler) % loads.len() as u64) as usize;
                if loads[home].total() < loads[home].capacity {
                    home
                } else {
                    least_loaded(loads)?
                }
            }
        };
        Some(DeviceId(pick))
    }
}

/// Index of the non-full device with the lowest total load (ties → lowest id).
fn least_loaded(loads: &[DeviceLoad]) -> Option<usize> {
    loads
        .iter()
        .enumerate()
        .filter(|(_, l)| !l.is_full())
        .min_by_key(|(i, l)| (l.total(), *i))
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(resident: usize, queued: usize) -> DeviceLoad {
        DeviceLoad { resident, queued, capacity: 4, max_queue: 4 }
    }

    #[test]
    fn round_robin_rotates_and_skips_full() {
        let mut r = Router::new(ShardPolicy::RoundRobin);
        let loads = [load(0, 0), load(4, 4), load(1, 0)];
        assert_eq!(r.route(SamplerKind::Ddpm, &loads), Some(DeviceId(0)));
        // Device 1 is full → skipped.
        assert_eq!(r.route(SamplerKind::Ddpm, &loads), Some(DeviceId(2)));
        assert_eq!(r.route(SamplerKind::Ddpm, &loads), Some(DeviceId(0)));
    }

    #[test]
    fn least_loaded_prefers_lowest_occupancy() {
        let mut r = Router::new(ShardPolicy::LeastLoaded);
        let loads = [load(3, 1), load(1, 0), load(2, 0)];
        assert_eq!(r.route(SamplerKind::Ddpm, &loads), Some(DeviceId(1)));
    }

    #[test]
    fn least_loaded_ties_break_by_id() {
        let mut r = Router::new(ShardPolicy::LeastLoaded);
        let loads = [load(2, 0), load(1, 1), load(2, 0)];
        assert_eq!(r.route(SamplerKind::Ddpm, &loads), Some(DeviceId(1)));
        let even = [load(1, 0), load(1, 0)];
        assert_eq!(r.route(SamplerKind::Ddpm, &even), Some(DeviceId(0)));
    }

    #[test]
    fn affinity_is_stable_per_signature_and_falls_back() {
        let mut r = Router::new(ShardPolicy::Affinity);
        let loads = [load(0, 0), load(0, 0), load(0, 0), load(0, 0)];
        let s = SamplerKind::Ddim { steps: 25 };
        let first = r.route(s, &loads).unwrap();
        for _ in 0..8 {
            assert_eq!(r.route(s, &loads), Some(first), "affinity must be stable");
        }
        // Distinct signatures should not all collapse onto one device.
        let spread: std::collections::BTreeSet<usize> = (1..64)
            .map(|steps| r.route(SamplerKind::Ddim { steps }, &loads).unwrap().0)
            .collect();
        assert!(spread.len() > 1, "signature hash must spread across devices");
        // Full home device falls back to least-loaded.
        let mut full = [load(0, 0); 4];
        full[first.0] = load(4, 4);
        let fallback = r.route(s, &full).unwrap();
        assert_ne!(fallback, first);
    }

    #[test]
    fn affinity_spills_once_home_slots_saturate() {
        // A homogeneous workload must not serialize onto one device: as
        // soon as the home device's batch slots are occupied, further
        // same-signature requests spread to the rest of the fleet.
        let mut r = Router::new(ShardPolicy::Affinity);
        let s = SamplerKind::Ddim { steps: 25 };
        let mut loads = vec![load(0, 0); 4];
        let mut used = std::collections::BTreeSet::new();
        for _ in 0..16 {
            let d = r.route(s, &loads).unwrap().0;
            used.insert(d);
            if loads[d].resident < loads[d].capacity {
                loads[d].resident += 1;
            } else {
                loads[d].queued += 1;
            }
        }
        assert_eq!(used.len(), 4, "16 one-signature requests must reach all 4 devices");
    }

    #[test]
    fn backpressure_when_all_full() {
        let mut r = Router::new(ShardPolicy::LeastLoaded);
        assert_eq!(r.route(SamplerKind::Ddpm, &[load(4, 4), load(4, 4)]), None);
        assert_eq!(r.route(SamplerKind::Ddpm, &[]), None);
    }

    #[test]
    fn prop_routing_invariants_under_random_load() {
        // XorShift-seeded random fleets: every policy must (a) never pick
        // a full device, (b) reject iff all devices are full, and (c) be
        // deterministic for identical inputs.
        crate::util::prop::forall("router invariants", 128, |g| {
            let n = g.usize_in(1, 8);
            let loads: Vec<DeviceLoad> = (0..n)
                .map(|_| DeviceLoad {
                    resident: g.usize_in(0, 4),
                    queued: g.usize_in(0, 4),
                    capacity: 4,
                    max_queue: 4,
                })
                .collect();
            let sampler = if g.bool() {
                SamplerKind::Ddpm
            } else {
                SamplerKind::Ddim { steps: g.usize_in(1, 100) }
            };
            for policy in [ShardPolicy::RoundRobin, ShardPolicy::LeastLoaded, ShardPolicy::Affinity] {
                let pick = Router::new(policy).route(sampler, &loads);
                let pick2 = Router::new(policy).route(sampler, &loads);
                assert_eq!(pick, pick2, "{} must be deterministic", policy.name());
                match pick {
                    Some(did) => assert!(!loads[did.0].is_full(), "{} picked a full device", policy.name()),
                    None => assert!(loads.iter().all(DeviceLoad::is_full), "{} rejected with room left", policy.name()),
                }
            }
        });
    }

    #[test]
    fn policy_parse_round_trips() {
        for p in [ShardPolicy::RoundRobin, ShardPolicy::LeastLoaded, ShardPolicy::Affinity] {
            assert_eq!(ShardPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(ShardPolicy::parse("bogus"), None);
    }
}
