//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The build image does not ship libxla, so this crate reproduces the
//! narrow API surface `difflight::runtime` uses — client, HLO loading,
//! compile, execute, literals — with a **simulated interpreter** behind
//! `execute`. Failure modes are preserved (missing HLO files and shape
//! mismatches still error), and execution is a deterministic, smooth,
//! timestep-sensitive function of the inputs so the serving stack above
//! it (samplers, batcher, cluster scheduler) exercises end to end with
//! reproducible, finite outputs. Swap this crate for the real bindings
//! by pointing the workspace `xla` path at them; no source changes
//! needed upstream.

use std::fmt;

/// Error type matching how the real bindings are consumed (`{e:?}`).
pub struct XlaError(String);

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

pub type Result<T> = std::result::Result<T, XlaError>;

/// A host literal: f32 buffer + shape, or a tuple of literals.
#[derive(Clone, Debug)]
pub enum Literal {
    Array { data: Vec<f32>, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal::Array { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reshape (element count must be preserved).
    pub fn reshape(self, dims: &[i64]) -> Result<Literal> {
        match self {
            Literal::Array { data, .. } => {
                let want: i64 = dims.iter().product();
                if want as usize != data.len() {
                    return Err(XlaError(format!(
                        "reshape: {} elems into {:?}",
                        data.len(),
                        dims
                    )));
                }
                Ok(Literal::Array { data, dims: dims.to_vec() })
            }
            Literal::Tuple(_) => Err(XlaError("reshape on tuple".into())),
        }
    }

    /// Unwrap a 1-tuple.
    pub fn to_tuple1(self) -> Result<Literal> {
        match self {
            Literal::Tuple(mut items) if items.len() == 1 => Ok(items.remove(0)),
            other => Err(XlaError(format!("not a 1-tuple: {other:?}"))),
        }
    }

    /// Copy out as a flat vector. Only f32 is supported.
    pub fn to_vec<T: FromF32>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { data, .. } => Ok(data.iter().map(|&v| T::from_f32(v)).collect()),
            Literal::Tuple(_) => Err(XlaError("to_vec on tuple".into())),
        }
    }

    fn dims(&self) -> &[i64] {
        match self {
            Literal::Array { dims, .. } => dims,
            Literal::Tuple(_) => &[],
        }
    }

    fn data(&self) -> &[f32] {
        match self {
            Literal::Array { data, .. } => data,
            Literal::Tuple(_) => &[],
        }
    }
}

/// Element conversion for [`Literal::to_vec`].
pub trait FromF32 {
    fn from_f32(v: f32) -> Self;
}

impl FromF32 for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }
}

/// Parsed HLO module (text retained for diagnostics only).
pub struct HloModuleProto {
    name: String,
    #[allow(dead_code)]
    text_len: usize,
}

impl HloModuleProto {
    /// Load HLO text from a file. Errors when the file is missing or
    /// empty — preserving the real bindings' failure mode for absent
    /// artifacts.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| XlaError(format!("read {path}: {e}")))?;
        if text.trim().is_empty() {
            return Err(XlaError(format!("{path}: empty HLO module")));
        }
        let name = text
            .lines()
            .find_map(|l| l.trim().strip_prefix("HloModule "))
            .unwrap_or("module")
            .split_whitespace()
            .next()
            .unwrap_or("module")
            .to_string();
        Ok(HloModuleProto { name, text_len: text.len() })
    }
}

/// An unoptimized computation ready to compile.
pub struct XlaComputation {
    name: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { name: proto.name.clone() }
    }
}

/// The PJRT client (simulated host backend).
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { platform: "sim-host" })
    }

    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { name: comp.name.clone() })
    }
}

/// A compiled executable. `execute` runs the simulated denoise step.
pub struct PjRtLoadedExecutable {
    #[allow(dead_code)]
    name: String,
}

/// Smooth per-sample ε̂ ≈ UNet(x, t): a tanh-squashed local mix of each
/// element with its neighbours, modulated by the timestep embedding.
/// Deterministic in (x, t); different t must yield different ε̂.
fn pseudo_unet(x: &[f32], t: f32) -> Vec<f32> {
    let n = x.len();
    // Timestep "embedding": two smooth scalar channels.
    let g = 0.85 + 0.15 * (t as f64 * 0.013).sin();
    let b = 0.05 * (t as f64 * 0.031).cos();
    let mut eps = Vec::with_capacity(n);
    for i in 0..n {
        let prev = x[if i == 0 { n - 1 } else { i - 1 }] as f64;
        let next = x[if i + 1 == n { 0 } else { i + 1 }] as f64;
        let mix = 0.8 * x[i] as f64 + 0.1 * prev + 0.1 * next;
        eps.push(((mix * g).tanh() + b) as f32);
    }
    eps
}

impl PjRtLoadedExecutable {
    /// Execute with (x: [b, h, w, c], t: [b]) → 1-tuple of ε̂ shaped like x.
    pub fn execute<L: AsHostLiteral>(&self, args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        if args.len() != 2 {
            return Err(XlaError(format!("expected 2 args, got {}", args.len())));
        }
        let x = args[0].as_literal();
        let t = args[1].as_literal();
        let xd = x.dims();
        if xd.len() != 4 {
            return Err(XlaError(format!("x must be rank 4, got {xd:?}")));
        }
        let batch = xd[0] as usize;
        let elems = (xd[1] * xd[2] * xd[3]) as usize;
        if t.data().len() != batch {
            return Err(XlaError(format!(
                "t has {} entries for batch {batch}",
                t.data().len()
            )));
        }
        let mut out = Vec::with_capacity(batch * elems);
        for bi in 0..batch {
            let row = &x.data()[bi * elems..(bi + 1) * elems];
            out.extend(pseudo_unet(row, t.data()[bi]));
        }
        let eps = Literal::Array { data: out, dims: xd.to_vec() };
        Ok(vec![vec![PjRtBuffer { literal: Literal::Tuple(vec![eps]) }]])
    }
}

/// Device buffer handle (host-resident here).
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// Input coercion for [`PjRtLoadedExecutable::execute`].
pub trait AsHostLiteral {
    fn as_literal(&self) -> &Literal;
}

impl AsHostLiteral for Literal {
    fn as_literal(&self) -> &Literal {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exe() -> PjRtLoadedExecutable {
        PjRtLoadedExecutable { name: "m".into() }
    }

    fn run(x: &[f32], dims: &[i64], t: &[f32]) -> Result<Vec<f32>> {
        let xl = Literal::vec1(x).reshape(dims)?;
        let tl = Literal::vec1(t);
        exe().execute::<Literal>(&[xl, tl])?[0][0]
            .to_literal_sync()?
            .to_tuple1()?
            .to_vec::<f32>()
    }

    #[test]
    fn deterministic_and_t_sensitive() {
        let x: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let a = run(&x, &[1, 4, 4, 1], &[10.0]).unwrap();
        let b = run(&x, &[1, 4, 4, 1], &[10.0]).unwrap();
        assert_eq!(a, b);
        let c = run(&x, &[1, 4, 4, 1], &[90.0]).unwrap();
        assert!(a.iter().zip(&c).any(|(p, q)| (p - q).abs() > 1e-4));
        assert!(a.iter().all(|v| v.is_finite() && v.abs() <= 1.1));
    }

    #[test]
    fn batch_rows_are_independent() {
        let x: Vec<f32> = (0..32).map(|i| (i as f32 * 0.11).cos()).collect();
        let two = run(&x, &[2, 4, 4, 1], &[5.0, 5.0]).unwrap();
        let one = run(&x[..16], &[1, 4, 4, 1], &[5.0]).unwrap();
        assert_eq!(&two[..16], &one[..]);
    }

    #[test]
    fn shape_errors() {
        assert!(Literal::vec1(&[0.0; 7]).reshape(&[2, 2, 2, 1]).is_err());
        let xl = Literal::vec1(&[0.0; 8]).reshape(&[2, 2, 2, 1]).unwrap();
        let tl = Literal::vec1(&[1.0]); // batch mismatch
        assert!(exe().execute::<Literal>(&[xl, tl]).is_err());
    }

    #[test]
    fn missing_file_errors() {
        assert!(HloModuleProto::from_text_file("/nonexistent/m.hlo.txt").is_err());
    }
}
