//! Figure 9 reproduction: "GOPS comparison across different diffusion
//! models" — DiffLight vs CPU, GPU, DeepCache, FPGA_Acc1, FPGA_Acc2,
//! PACE on all four Table I workloads.
//!
//! Prints the per-model GOPS series (the figure's grouped bars) and the
//! average improvement ratios the paper quotes: 59.5×, 51.89×, 192×,
//! 572×, 94×, 5.5×.

#[path = "harness.rs"]
mod harness;

use difflight::arch::cost::OptFlags;
use difflight::baselines::all_baselines;
use difflight::sim::Simulator;
use difflight::util::stats;
use difflight::workload::{ModelId, ModelSpec};

const PAPER_RATIOS: [(&str, f64); 6] = [
    ("CPU", 59.5),
    ("GPU", 51.89),
    ("DeepCache", 192.0),
    ("FPGA_Acc1", 572.0),
    ("FPGA_Acc2", 94.0),
    ("PACE", 5.5),
];

fn main() {
    harness::section("Figure 9: GOPS per model per platform");
    let sim = Simulator::paper_optimal();
    let baselines = all_baselines();

    // Header.
    print!("{:<18} {:>12}", "model", "DiffLight");
    for b in &baselines {
        print!(" {:>12}", b.name());
    }
    println!();

    let mut dl = Vec::new();
    let mut platform_gops: Vec<Vec<f64>> = vec![Vec::new(); baselines.len()];
    for id in ModelId::ALL {
        let spec = ModelSpec::get(id);
        let run = sim.run_model(&spec, OptFlags::ALL);
        dl.push(run.gops());
        print!("{:<18} {:>12.1}", spec.id.name(), run.gops());
        for (bi, b) in baselines.iter().enumerate() {
            let r = b.run(&spec);
            platform_gops[bi].push(r.gops);
            print!(" {:>12.2}", r.gops);
        }
        println!();
    }

    harness::section("average improvement ratios (ours vs paper)");
    for (bi, (name, paper)) in PAPER_RATIOS.iter().enumerate() {
        let ratios: Vec<f64> = dl
            .iter()
            .zip(&platform_gops[bi])
            .map(|(d, p)| d / p)
            .collect();
        let ours = stats::mean(&ratios);
        println!("{name:<10} ours {ours:8.2}x   paper {paper:>7.2}x");
        assert!(
            (ours / paper - 1.0).abs() < 0.25,
            "{name}: ratio {ours:.2} vs paper {paper}"
        );
    }

    harness::section("timing");
    let spec = ModelSpec::get(ModelId::StableDiffusion);
    harness::bench("run_model(SD, ALL)", 30, || {
        harness::black_box(sim.run_model(&spec, OptFlags::ALL));
    });
    harness::bench("baseline GPU run(SD)", 100, || {
        harness::black_box(baselines[1].run(&spec));
    });
}
