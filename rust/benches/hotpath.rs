//! Hot-path micro-benchmarks for the perf pass (EXPERIMENTS.md §Perf).
//!
//! Times the L3 components that sit on critical paths: simulator layer
//! costing, trace construction, sampler arithmetic, batcher churn, JSON
//! reporting, the DSE thread-pool sweep — and, when `artifacts/` exists,
//! the real PJRT denoise step (the serving hot path).

#[path = "harness.rs"]
mod harness;

use difflight::arch::cost::OptFlags;
use difflight::coordinator::batcher::{BatchPolicy, DynamicBatcher};
use difflight::coordinator::request::{GenerationRequest, SamplerKind};
use difflight::coordinator::sampler::{initial_noise, DdpmSampler, Sampler};
use difflight::runtime::manifest::NoiseSchedule;
use difflight::runtime::Runtime;
use difflight::sim::Simulator;
use difflight::util::rng::XorShift;
use difflight::util::threadpool::ThreadPool;
use difflight::workload::{ModelId, ModelSpec};
use std::time::{Duration, Instant};

fn main() {
    harness::section("L3 simulator hot path");
    let sim = Simulator::paper_optimal();
    let sd_trace = ModelSpec::get(ModelId::StableDiffusion).trace();
    let ddpm_trace = ModelSpec::get(ModelId::DdpmCifar10).trace();
    harness::bench("trace build (SD)", 50, || {
        harness::black_box(ModelSpec::get(ModelId::StableDiffusion).trace());
    });
    harness::bench("step_cost SD (ALL)", 100, || {
        harness::black_box(sim.step_cost(&sd_trace, OptFlags::ALL));
    });
    harness::bench("step_cost DDPM (BASELINE)", 100, || {
        harness::black_box(sim.step_cost(&ddpm_trace, OptFlags::BASELINE));
    });

    harness::section("coordinator primitives");
    let schedule = NoiseSchedule::linear(1000);
    let sampler = DdpmSampler::new(schedule);
    let mut x = initial_noise(3, 256 * 64);
    let eps = initial_noise(4, 256 * 64);
    let mut rng = XorShift::new(9);
    harness::bench("ddpm sampler step (16k elems)", 200, || {
        sampler.step(500, &mut x, &eps, &mut rng);
    });
    harness::bench("initial_noise (16k elems)", 200, || {
        harness::black_box(initial_noise(11, 256 * 64));
    });
    harness::bench("batcher push+form (256 reqs)", 100, || {
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(0),
        });
        for i in 0..256 {
            b.push(GenerationRequest::new(i, i, SamplerKind::Ddpm));
        }
        let now = Instant::now();
        while harness::black_box(b.try_form(now)).is_some() {}
    });

    harness::section("parallel sweep infrastructure");
    let pool = ThreadPool::new(8);
    harness::bench("threadpool map 64 sim runs", 5, || {
        let specs: Vec<ModelId> = (0..64).map(|i| ModelId::ALL[i % 4]).collect();
        // §Perf: simulator construction hoisted out of the per-item
        // closure (it is cheap but not free; the sweep reuses one).
        let sim = Simulator::paper_optimal();
        let out = pool.map(specs, move |id| {
            sim.run_model(&ModelSpec::get(id), OptFlags::ALL).gops()
        });
        harness::black_box(out);
    });

    harness::section("PJRT serving hot path (needs artifacts/)");
    match Runtime::open("artifacts") {
        Ok(mut rt) => {
            let elems = rt.manifest.sample_elems();
            let compile_t0 = Instant::now();
            let _ = rt.denoise(1, true).expect("compile b1");
            println!("compile w8a8 b1: {:.2}s (one-time)", compile_t0.elapsed().as_secs_f64());
            let x = initial_noise(3, elems);
            {
                let exe = rt.denoise(1, true).unwrap();
                harness::bench("UNet step w8a8 b1", 5, || {
                    harness::black_box(exe.predict_noise(&x, &[50.0]).unwrap());
                });
            }
            if rt.manifest.quantized_batches().contains(&4) {
                let compile_t0 = Instant::now();
                let _ = rt.denoise(4, true).expect("compile b4");
                println!(
                    "compile w8a8 b4: {:.2}s (one-time)",
                    compile_t0.elapsed().as_secs_f64()
                );
                let x4 = initial_noise(5, 4 * elems);
                let exe4 = rt.denoise(4, true).unwrap();
                harness::bench("UNet step w8a8 b4", 5, || {
                    harness::black_box(exe4.predict_noise(&x4, &[50.0; 4]).unwrap());
                });
            }
        }
        Err(e) => println!("skipped (no artifacts): {e}"),
    }
}
