//! Photonic loss budget and laser-power solver (paper §V).
//!
//! "Factors contributing to photonic signal losses, such as waveguide
//! propagation (1 dB/cm), splitter (0.13 dB), MR through (0.02 dB) and MR
//! modulation (0.72 dB) losses are taken into account when determining
//! appropriate laser power."
//!
//! The solver walks the optical path of one row of an MR bank array,
//! accumulates worst-case loss in dB, and back-computes the per-wavelength
//! laser output power needed for the photodetector to stay above its
//! sensitivity floor.

use super::params::DeviceParams;

/// Itemised loss budget for one optical path (all in dB).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LossBudget {
    pub propagation_db: f64,
    pub splitter_db: f64,
    pub mr_through_db: f64,
    pub mr_modulation_db: f64,
}

impl LossBudget {
    pub fn total_db(&self) -> f64 {
        self.propagation_db + self.splitter_db + self.mr_through_db + self.mr_modulation_db
    }
}

/// Describe the optical path of one row in a block.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpticalPath {
    /// Physical waveguide length in centimetres.
    pub waveguide_length_cm: f64,
    /// Splitters traversed (power splits for broadcast).
    pub splitters: usize,
    /// MRs passed *by* without interacting (through loss each).
    pub mrs_passed: usize,
    /// MRs that actively modulate the signal (modulation loss each).
    pub mrs_modulating: usize,
}

impl OpticalPath {
    /// Path for one row of a two-bank block (activation bank then weight
    /// bank): the signal interacts with 2 MRs (its own wavelength in each
    /// bank) and passes the other `2·(λ−1)` rings.
    pub fn two_bank_row(wavelengths: usize, waveguide_length_cm: f64, splitters: usize) -> Self {
        assert!(wavelengths >= 1);
        Self {
            waveguide_length_cm,
            splitters,
            mrs_passed: 2 * (wavelengths - 1),
            mrs_modulating: 2,
        }
    }

    /// Compute the loss budget under the given device parameters.
    pub fn budget(&self, params: &DeviceParams) -> LossBudget {
        LossBudget {
            propagation_db: self.waveguide_length_cm * params.waveguide_loss_db_per_cm,
            splitter_db: self.splitters as f64 * params.splitter_loss_db,
            mr_through_db: self.mrs_passed as f64 * params.mr_through_loss_db,
            mr_modulation_db: self.mrs_modulating as f64 * params.mr_modulation_loss_db,
        }
    }
}

/// Result of the laser-power solve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LaserSolve {
    /// Required laser output per wavelength, dBm.
    pub required_dbm: f64,
    /// Required laser output per wavelength, mW.
    pub required_mw: f64,
    /// Wall-plug electrical power per wavelength, mW.
    pub electrical_mw: f64,
    /// Total path loss, dB.
    pub loss_db: f64,
}

/// Solve for the laser power one wavelength needs so the PD receives at
/// least its sensitivity floor after all path losses.
pub fn solve_laser_power(path: &OpticalPath, params: &DeviceParams) -> LaserSolve {
    let loss_db = path.budget(params).total_db();
    let required_dbm = params.pd_sensitivity_dbm + loss_db;
    let required_mw = 10f64.powf(required_dbm / 10.0);
    let electrical_mw = required_mw / params.laser_wall_plug_efficiency;
    LaserSolve { required_dbm, required_mw, electrical_mw, loss_db }
}

/// Check the 36-MR design rule for a proposed wavelength count.
pub fn check_mr_design_rule(wavelengths: usize, params: &DeviceParams) -> crate::Result<()> {
    if wavelengths > params.max_mrs_per_waveguide {
        anyhow::bail!(
            "{} wavelengths exceed the {}-MR/waveguide error-free design rule",
            wavelengths,
            params.max_mrs_per_waveguide
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> DeviceParams {
        DeviceParams::paper()
    }

    #[test]
    fn budget_itemisation() {
        let p = params();
        let path = OpticalPath {
            waveguide_length_cm: 2.0,
            splitters: 3,
            mrs_passed: 10,
            mrs_modulating: 2,
        };
        let b = path.budget(&p);
        assert!((b.propagation_db - 2.0).abs() < 1e-12);
        assert!((b.splitter_db - 0.39).abs() < 1e-12);
        assert!((b.mr_through_db - 0.2).abs() < 1e-12);
        assert!((b.mr_modulation_db - 1.44).abs() < 1e-12);
        assert!((b.total_db() - 4.03).abs() < 1e-12);
    }

    #[test]
    fn two_bank_row_path() {
        let path = OpticalPath::two_bank_row(36, 1.0, 1);
        assert_eq!(path.mrs_passed, 70);
        assert_eq!(path.mrs_modulating, 2);
    }

    #[test]
    fn laser_power_covers_loss() {
        let p = params();
        let path = OpticalPath::two_bank_row(36, 1.0, 2);
        let s = solve_laser_power(&path, &p);
        // received = required − loss = sensitivity floor exactly
        assert!((s.required_dbm - s.loss_db - p.pd_sensitivity_dbm).abs() < 1e-12);
        assert!(s.required_mw > 0.0);
        assert!(s.electrical_mw > s.required_mw); // wall plug < 100%
    }

    #[test]
    fn more_wavelengths_cost_more_power() {
        let p = params();
        let a = solve_laser_power(&OpticalPath::two_bank_row(8, 1.0, 1), &p);
        let b = solve_laser_power(&OpticalPath::two_bank_row(36, 1.0, 1), &p);
        assert!(b.required_mw > a.required_mw);
    }

    #[test]
    fn design_rule_enforced() {
        let p = params();
        assert!(check_mr_design_rule(36, &p).is_ok());
        assert!(check_mr_design_rule(37, &p).is_err());
    }

    #[test]
    fn worst_case_36_wavelength_path_is_feasible() {
        // Sanity: the full-size DiffLight row must need < 10 mW optical
        // per wavelength, else the architecture wouldn't be buildable.
        let p = params();
        let path = OpticalPath::two_bank_row(36, 1.5, 3);
        let s = solve_laser_power(&path, &p);
        assert!(
            s.required_mw < 10.0,
            "required {:.3} mW — loss budget implausible",
            s.required_mw
        );
    }
}
