//! DiffLight CLI — the leader entrypoint.
//!
//! Subcommands:
//!
//! * `simulate [--model all|ddpm|ldm1|ldm2|sd] [--sparse] [--pipelined]
//!   [--dac-sharing] [--all-opts]` — run the accelerator simulator and
//!   print GOPS/EPB per model.
//! * `compare` — the Figure 9/10 platform comparison table.
//! * `dse [--threads N]` — design-space exploration (reports the top
//!   configurations and the paper config's rank).
//! * `dse-fleet [--budget MRS | --budget-dies N] [--trace N] [--steps S]
//!   [--gap-us G] [--slo-ms MS[,MS...]] [--slo-target F] [--rungs R]
//!   [--keep F] [--threads N] [--oracle]` — fleet-composition search:
//!   sweep profile-group × count fleets under a total-MR silicon budget
//!   against a fixed synthetic trace and rank them by goodput per joule
//!   at the target SLO attainment. The sweep runs parallel, memoized
//!   (a second invocation of the same sweep is all memo hits) and
//!   successive-halving-pruned; `--oracle` also runs the exhaustive
//!   unpruned sweep and fails (exit 3) if the pruned winner lands more
//!   than 2% below the unpruned optimum or the re-sweep missed the
//!   memo. Grammar details in `rust/src/dse/README.md`.
//! * `serve [--requests N] [--batch B] [--steps S] [--artifacts DIR]
//!   [--fp32] [--devices N] [--reuse-interval K] [--policy P]
//!   [--fleet SPEC | --fleet-file PATH] [--slo-ms MS[,MS...]]
//!   [--shed-late]` — serve synthetic generation requests through the
//!   AOT UNet via PJRT (sharded across a device fleet when more than
//!   one device is specified, with DeepCache step reuse when `K > 1`)
//!   and print latency/throughput metrics. `--slo-ms` attaches
//!   per-class latency deadlines on the fleet path; `--shed-late`
//!   drops requests that cannot meet them at admission.
//! * `cluster [--devices N] [--requests R] [--steps S] [--capacity C]
//!   [--policy rr|ll|affinity] [--gap-us G] [--reuse-interval K]
//!   [--shallow-frac F] [--no-steal] [--occupancy-only]
//!   [--fleet SPEC | --fleet-file PATH]
//!   [--arrival poisson:RATE|burst:RATE:DUTY | --clients N:THINK_MS]
//!   [--slo-ms MS[,MS...]] [--shed-late] [--backlog B]
//!   [--faults SPEC | --faults-file PATH] [--no-migration]
//!   [--shards N|auto]` —
//!   pure-simulation fleet serving (no artifacts needed): continuous
//!   step-level batching over simulated DiffLight devices — homogeneous
//!   (`--devices`) or heterogeneous
//!   (`--fleet "Y8N12K3H8L6M3:cap4x2,Y2N12K3H3L6M3x6"`, per-device
//!   `[Y,N,K,H,L,M]@λ` profiles priced independently) — with cost-aware
//!   routing, work stealing and DeepCache-style step reuse, plus a
//!   fleet JSON report with per-profile roll-ups. Load is a live
//!   arrival stream: the default replayed synthetic workload, an
//!   open-loop Poisson/burst process (`--arrival`), or closed-loop
//!   clients (`--clients`); `--slo-ms`/`--shed-late` add the SLO tier
//!   (goodput, attainment, deadline-aware admission).
//!   `--faults "crash@t=T:dev=N,down@t=T:mttr=S,recal:mtbf=S:mttr=S"`
//!   (or `--faults-file faults.json`) injects deterministic device
//!   churn — crashes, thermal-recalibration outages, straggler onset —
//!   with step-boundary checkpoint/migrate recovery of victim requests
//!   (`--no-migration` ablates it so victims are lost instead).
//!   `--trace FILE` attaches the flight recorder and writes per-request
//!   lifecycle events as JSON lines. Grammars are documented in
//!   `rust/src/cluster/README.md`.
//! * `trace replay FILE [FILE2] [--expect report.json]` — reconstruct a
//!   run from a flight-recorder trace: recompute the latency/queue
//!   histograms and counters from the events alone and print the
//!   summary. With `--expect`, verify the replay matches a live
//!   `cluster_report.json` bit-for-bit; with a second FILE, diff two
//!   traces (first divergent event + per-device routing deltas).
//! * `devices` — print the Table II device parameter set in use.

use difflight::arch::cost::OptFlags;
use difflight::baselines::all_baselines;
use difflight::cluster::load::{
    parse_arrival_spec, parse_clients_spec, parse_fault_spec, parse_slo_spec,
};
use difflight::cluster::trace::{
    check_against_report, diff, parse_jsonl_versioned, replay, replay_summary,
};
use difflight::cluster::{
    parse_brownout_spec, parse_faults_json, parse_fleet_json, parse_fleet_spec, parse_retry_spec,
    synthetic_workload, Cluster, ClusterConfig, DeviceProfile, FaultPlan, HedgePolicy,
    RequestSource, ShardMap, ShardPolicy, SimExecutor, TraceEvent, TraceSink,
};
use difflight::coordinator::request::SamplerKind;
use difflight::coordinator::{Coordinator, EngineConfig};
use difflight::devices::DeviceParams;
use difflight::dse::{
    explore, explore_fleet, explore_fleet_unpruned, DesignSpace, FleetKnobs, FleetMemo,
    FleetSpace, FleetTrace,
};
use difflight::sim::Simulator;
use difflight::util::cli::Args;
use difflight::util::json::Json;
use difflight::util::table::{fmt_ratio, fmt_si, Table};
use difflight::workload::{ModelId, ModelSpec};

fn main() {
    let args = Args::from_env();
    let cmd = args.positional(0).unwrap_or("help").to_string();
    let code = match cmd.as_str() {
        "simulate" => cmd_simulate(&args),
        "compare" => cmd_compare(),
        "dse" => cmd_dse(&args),
        "dse-fleet" => cmd_dse_fleet(&args),
        "serve" => cmd_serve(&args),
        "cluster" => cmd_cluster(&args),
        "trace" => cmd_trace(&args),
        "devices" => cmd_devices(),
        _ => {
            print_help(args.program());
            0
        }
    };
    std::process::exit(code);
}

fn print_help(program: &str) {
    println!("DiffLight — silicon-photonics accelerator for diffusion models");
    println!("usage: {program} <simulate|compare|dse|dse-fleet|serve|cluster|trace|devices> [options]");
    println!("  simulate --model all --all-opts     simulator GOPS/EPB");
    println!("  compare                             Figure 9/10 comparison");
    println!("  dse --threads 8                     design-space exploration");
    println!("  dse-fleet --budget-dies 8           fleet-composition search (goodput/J)");
    println!("            --budget 500000           ...or an explicit total-MR silicon budget");
    println!("            --trace 96 --steps 8      synthetic trace size / DDIM steps");
    println!("            --slo-ms 2,10             per-class SLOs (--slo-target 0.99)");
    println!("            --rungs 3 --keep 0.5      successive-halving schedule");
    println!("            --oracle                  verify against the unpruned sweep (exit 3 on drift)");
    println!("  serve --requests 8 --steps 25       serve via PJRT artifacts");
    println!("  cluster --devices 4 --requests 32   simulated fleet serving");
    println!("          --reuse-interval 3          DeepCache step reuse (1 = off)");
    println!("          --fleet \"Y8N12K3H8L6M3:cap4x2,Y2N12K3H3L6M3x6\"");
    println!("                                      heterogeneous per-device profiles");
    println!("          --fleet-file fleet.json     fleet spec as JSON");
    println!("          --occupancy-only            disable cost-aware routing");
    println!("          --arrival poisson:2000      open-loop arrivals (or burst:RATE:DUTY)");
    println!("          --clients 8:50              closed-loop clients (think time in ms)");
    println!("          --slo-ms 30,100             per-class latency SLOs");
    println!("          --shed-late                 deadline-aware admission shedding");
    println!("          --backlog 64                fleet-level deferral queue (0 = shed)");
    println!("          --faults \"crash@t=0.002:dev=3,down@t=0.001:mttr=0.016\"");
    println!("                                      deterministic device churn (also recal:mtbf=S:mttr=S, slow@t=T:factor=F)");
    println!("          --faults-file faults.json   fault plan as JSON");
    println!("          --no-migration              lose fault victims instead of checkpoint/migrate");
    println!("          --retry \"max=3:base-ms=5\"   re-admit shed/lost requests with exponential backoff (:budget=B)");
    println!("          --hedge-ms 40               duplicate stragglers past a fixed latency threshold");
    println!("          --hedge-q 0.95              ...or past a quantile of observed completion latency");
    println!("          --brownout \"target=0.99:window=64\"");
    println!("                                      degrade timestep tiers before shedding (also :max=L:factor=F)");
    println!("          --shards 4                  partition the fleet into parallel event shards (auto = worker count)");
    println!("          --trace trace.jsonl         flight recorder: per-request events as JSON lines");
    println!("  trace replay FILE                   rebuild metrics from a recorded trace");
    println!("        replay FILE --expect artifacts/cluster_report.json");
    println!("                                      verify replay matches the live report exactly");
    println!("        replay FILE FILE2             diff two traces (first divergence, route deltas)");
    println!("  devices                             Table II constants");
}

/// Build the fleet part of a [`ClusterConfig`] from `--fleet` /
/// `--fleet-file`, or from the homogeneous `--devices`-style flags.
/// The two forms are mutually exclusive: per-device knobs belong in the
/// spec (`:cap4:q64:reuse3`) when a fleet is given, so combining them
/// with the homogeneous flags is an error rather than a silent drop.
/// Errors (bad grammar, design-rule violations, unreadable file) come
/// back to the caller for a clean non-zero exit.
fn fleet_from_args(args: &Args, default_devices: usize) -> difflight::Result<ClusterConfig> {
    let explicit_fleet = args.get("fleet").is_some() || args.get("fleet-file").is_some();
    if explicit_fleet {
        anyhow::ensure!(
            args.get("fleet").is_none() || args.get("fleet-file").is_none(),
            "--fleet and --fleet-file are mutually exclusive"
        );
        for flag in ["devices", "capacity", "max-queue", "reuse-interval", "shallow-frac"] {
            anyhow::ensure!(
                args.get(flag).is_none(),
                "--{flag} conflicts with --fleet/--fleet-file; put per-device knobs in \
                 the fleet spec instead (e.g. \":cap4:q64:reuse3\" — see \
                 rust/src/cluster/README.md)"
            );
        }
    }
    let mut config = if let Some(spec) = args.get("fleet") {
        ClusterConfig::heterogeneous(parse_fleet_spec(spec)?)
    } else if let Some(path) = args.get("fleet-file") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("--fleet-file {path}: {e}"))?;
        ClusterConfig::heterogeneous(parse_fleet_json(&text)?)
    } else {
        let profile = DeviceProfile {
            capacity: args.get_parsed("capacity", 4usize),
            max_queue: args.get_parsed("max-queue", 64usize),
            reuse_interval: args.get_parsed("reuse-interval", 1usize).max(1),
            reuse_shallow_frac: args.get_parsed("shallow-frac", 0.25f64).clamp(0.01, 1.0),
            ..DeviceProfile::default()
        };
        ClusterConfig::homogeneous(profile, args.get_parsed("devices", default_devices))
    };
    config.work_stealing = !args.flag("no-steal");
    config.cost_aware = !args.flag("occupancy-only");
    Ok(config)
}

/// The valid load-model flag combinations, for conflict error messages.
/// `--slo-ms`/`--shed-late` decorate whatever source is selected, so
/// they compose with every row.
const LOAD_COMBOS: &str = "valid combinations (each composes with \
     [--slo-ms MS[,MS...]] [--shed-late]):\n  \
     replay (default): --requests N [--gap-us G] [--seed S]\n  \
     open loop:        --arrival poisson:RATE|burst:RATE:DUTY --requests N\n  \
     closed loop:      --clients N[:THINK_MS] --requests N";

/// Build the request source for the `cluster` subcommand from the load
/// flags (`--arrival` / `--clients` / `--slo-ms` / `--shed-late` /
/// `--gap-us`), with strict conflict checking: the arrival-model flags
/// replace the replayed synthetic generator, so combining them with
/// each other or with the replay-style `--gap-us` is an error listing
/// the valid combinations (matching the `--fleet` conflict rules).
/// Returns the source and the parsed SLOs.
fn request_source_from_args(
    args: &Args,
    requests: usize,
    seed: u64,
    sampler: difflight::coordinator::request::SamplerKind,
) -> difflight::Result<(RequestSource, Vec<f64>)> {
    let arrival = args.get("arrival");
    let clients = args.get("clients");
    anyhow::ensure!(
        arrival.is_none() || clients.is_none(),
        "--arrival (open loop) and --clients (closed loop) are mutually exclusive; {LOAD_COMBOS}"
    );
    if args.get("gap-us").is_some() {
        for (flag, given) in [("arrival", arrival.is_some()), ("clients", clients.is_some())] {
            anyhow::ensure!(
                !given,
                "--gap-us configures the replayed synthetic workload and conflicts with \
                 --{flag}; {LOAD_COMBOS}\n(--gap-us G is --arrival poisson:RATE with \
                 RATE = 1e6/G)"
            );
        }
    }
    let slos_s = match args.get("slo-ms") {
        Some(spec) => parse_slo_spec(spec)?,
        None => Vec::new(),
    };
    anyhow::ensure!(
        !args.flag("shed-late") || !slos_s.is_empty(),
        "--shed-late needs deadlines to shed against; add --slo-ms MS[,MS...]"
    );
    let source = if let Some(spec) = arrival {
        parse_arrival_spec(spec, requests, seed, sampler)?
    } else if let Some(spec) = clients {
        parse_clients_spec(spec, requests, seed, sampler)?
    } else {
        let gap_s = args.get_parsed("gap-us", 0.0f64) * 1e-6;
        RequestSource::replay(synthetic_workload(requests, seed, sampler, gap_s))
    };
    Ok((source.with_slos(slos_s.clone()), slos_s))
}

/// Parse `--policy`, or exit-worthy error text listing the valid names.
fn policy_from_args(args: &Args) -> Result<ShardPolicy, String> {
    let raw = args.get_or("policy", "least-loaded");
    ShardPolicy::parse(&raw).ok_or_else(|| {
        format!("unknown --policy {raw:?}; valid policies: {}", ShardPolicy::names())
    })
}

fn parse_opts(args: &Args) -> OptFlags {
    if args.flag("all-opts") {
        OptFlags::ALL
    } else {
        OptFlags {
            sparse: args.flag("sparse"),
            pipelined: args.flag("pipelined"),
            dac_sharing: args.flag("dac-sharing"),
        }
    }
}

fn models_from(arg: &str) -> Vec<ModelId> {
    match arg {
        "ddpm" => vec![ModelId::DdpmCifar10],
        "ldm1" => vec![ModelId::LdmChurches],
        "ldm2" => vec![ModelId::LdmBeds],
        "sd" => vec![ModelId::StableDiffusion],
        _ => ModelId::ALL.to_vec(),
    }
}

fn cmd_simulate(args: &Args) -> i32 {
    let opts = parse_opts(args);
    let sim = Simulator::paper_optimal();
    let mut table = Table::new(&["model", "timesteps", "latency", "energy", "GOPS", "EPB"]);
    for id in models_from(&args.get_or("model", "all")) {
        let spec = ModelSpec::get(id);
        let run = sim.run_model(&spec, opts);
        table.row(&[
            spec.id.name().to_string(),
            spec.timesteps.to_string(),
            fmt_si(run.total.latency_s, "s"),
            fmt_si(run.total.energy_j, "J"),
            format!("{:.1}", run.gops()),
            fmt_si(run.epb(), "J/bit"),
        ]);
    }
    println!("DiffLight {} opts={:?}", sim.accelerator.config, opts);
    print!("{}", table.render());
    0
}

fn cmd_compare() -> i32 {
    let sim = Simulator::paper_optimal();
    let mut table = Table::new(&["platform", "avg GOPS", "avg EPB", "GOPS ratio", "EPB ratio"]);
    let mut dl_gops = Vec::new();
    let mut dl_epb = Vec::new();
    for id in ModelId::ALL {
        let run = sim.run_model(&ModelSpec::get(id), OptFlags::ALL);
        dl_gops.push(run.gops());
        dl_epb.push(run.epb());
    }
    let dg = difflight::util::stats::mean(&dl_gops);
    let de = difflight::util::stats::mean(&dl_epb);
    table.row(&[
        "DiffLight".into(),
        format!("{dg:.1}"),
        fmt_si(de, "J/bit"),
        "1x".into(),
        "1x".into(),
    ]);
    for b in all_baselines() {
        let mut gops = Vec::new();
        let mut epb = Vec::new();
        let mut gr = Vec::new();
        let mut er = Vec::new();
        for (i, id) in ModelId::ALL.iter().enumerate() {
            let r = b.run(&ModelSpec::get(*id));
            gops.push(r.gops);
            epb.push(r.epb_j_per_bit);
            gr.push(dl_gops[i] / r.gops);
            er.push(r.epb_j_per_bit / dl_epb[i]);
        }
        table.row(&[
            b.name().to_string(),
            format!("{:.2}", difflight::util::stats::mean(&gops)),
            fmt_si(difflight::util::stats::mean(&epb), "J/bit"),
            fmt_ratio(difflight::util::stats::mean(&gr)),
            fmt_ratio(difflight::util::stats::mean(&er)),
        ]);
    }
    print!("{}", table.render());
    0
}

fn cmd_dse(args: &Args) -> i32 {
    let threads = args.get_parsed("threads", 8usize);
    let params = DeviceParams::paper();
    let points = explore(&DesignSpace::paper(), &params, threads);
    let mut table = Table::new(&["rank", "[Y,N,K,H,L,M]", "MRs", "avg GOPS", "avg EPB", "GOPS/EPB"]);
    for (i, pt) in points.iter().take(10).enumerate() {
        table.row(&[
            (i + 1).to_string(),
            format!("{:?}", pt.config.vector()),
            pt.total_mrs.to_string(),
            format!("{:.1}", pt.avg_gops),
            fmt_si(pt.avg_epb, "J/bit"),
            format!("{:.3e}", pt.objective),
        ]);
    }
    print!("{}", table.render());
    if let Some(rank) = points
        .iter()
        .position(|pt| pt.config.vector() == difflight::PAPER_OPTIMAL_CONFIG)
    {
        println!(
            "paper config [4,12,3,6,6,3]: rank {}/{} (top {:.1}%)",
            rank + 1,
            points.len(),
            100.0 * (rank + 1) as f64 / points.len() as f64
        );
    }
    0
}

/// `dse-fleet`: fleet-composition search over a [`FleetSpace`] under a
/// total-MR silicon budget, ranked by goodput per joule at the target
/// SLO attainment. Runs the pruned+memoized sweep twice (the re-sweep
/// demonstrates the fleet memo); `--oracle` adds the exhaustive
/// unpruned sweep and turns the 2%-winner and memo-hit checks into the
/// exit code (3 on failure) — the verify.sh smoke gate.
fn cmd_dse_fleet(args: &Args) -> i32 {
    let budget = match args.get("budget") {
        Some(raw) => match raw.parse::<usize>() {
            Ok(n) if n > 0 => n,
            _ => {
                eprintln!("error: --budget {raw}: expected a positive total-MR count");
                return 2;
            }
        },
        None => args.get_parsed("budget-dies", 8usize).max(1) * FleetSpace::paper_die_mrs(),
    };
    let threads = args.get_parsed("threads", 8usize).max(1);
    let rungs = args.get_parsed("rungs", 3usize).max(1);
    let keep = args.get_parsed("keep", 0.5f64);
    if !(0.0..=1.0).contains(&keep) || keep == 0.0 {
        eprintln!("error: --keep {keep}: expected a fraction in (0, 1]");
        return 2;
    }
    let requests = args.get_parsed("trace", 96usize).max(1);
    let steps = args.get_parsed("steps", 8usize).max(1);
    let seed = args.get_parsed("seed", 1u64);
    let gap_s = args.get_parsed("gap-us", 200.0f64) * 1e-6;
    let target = args.get_parsed("slo-target", 0.99f64);
    if !(0.0..=1.0).contains(&target) {
        eprintln!("error: --slo-target {target}: expected a fraction in [0, 1]");
        return 2;
    }
    let slos_s = match parse_slo_spec(&args.get_or("slo-ms", "2,10")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 2;
        }
    };
    let space = FleetSpace::paper(budget);
    let candidates = space.candidates();
    if candidates.is_empty() {
        eprintln!(
            "error: no fleet fits a {budget}-MR budget (the smallest menu die needs {} MRs)",
            space.menu.iter().map(|p| p.arch.total_mrs()).min().unwrap_or(0)
        );
        return 2;
    }
    let trace =
        FleetTrace::synthetic(requests, seed, SamplerKind::Ddim { steps }, gap_s, slos_s);
    let knobs = FleetKnobs::default();
    let memo = std::sync::Arc::new(FleetMemo::new());

    let t0 = std::time::Instant::now();
    let points = explore_fleet(&space, &trace, &knobs, target, rungs, keep, threads, &memo);
    let sweep_s = t0.elapsed().as_secs_f64();
    let cold = memo.stats();
    let t1 = std::time::Instant::now();
    let again = explore_fleet(&space, &trace, &knobs, target, rungs, keep, threads, &memo);
    let resweep_s = t1.elapsed().as_secs_f64();
    let warm = memo.stats().delta(&cold);
    if points.is_empty() {
        eprintln!("error: no candidate produced a score (all simulations failed)");
        return 1;
    }

    println!(
        "fleet DSE: {} candidates under {budget} MRs, {} trace requests, {} rung(s) keep {keep}, {} thread(s)",
        candidates.len(),
        trace.len(),
        rungs,
        threads,
    );
    let mut table = Table::new(&[
        "rank", "fleet", "dev", "MRs", "good/s", "attain", "energy", "samples/J",
    ]);
    for (i, pt) in points.iter().take(10).enumerate() {
        table.row(&[
            (i + 1).to_string(),
            pt.spec.clone(),
            pt.devices.to_string(),
            pt.total_mrs.to_string(),
            format!("{:.1}", pt.goodput_samples_per_s),
            format!("{:.1}%", 100.0 * pt.attainment),
            fmt_si(pt.energy_j, "J"),
            format!("{:.3e}", pt.objective),
        ]);
    }
    print!("{}", table.render());
    println!(
        "sweep {} (cold: {} sims, {} memo hits) → re-sweep {} ({} hits, {} misses)",
        fmt_si(sweep_s, "s"),
        cold.misses,
        cold.hits,
        fmt_si(resweep_s, "s"),
        warm.hits,
        warm.misses,
    );

    let mut failed = false;
    if args.flag("oracle") {
        let t2 = std::time::Instant::now();
        let oracle = explore_fleet_unpruned(&space, &trace, &knobs, target);
        let oracle_s = t2.elapsed().as_secs_f64();
        let best = oracle.first().map(|p| p.objective).unwrap_or(0.0);
        let got = points[0].objective;
        println!(
            "oracle: unpruned optimum {} = {:.3e} samples/J in {} ({} sims)",
            oracle.first().map(|p| p.spec.as_str()).unwrap_or("-"),
            best,
            fmt_si(oracle_s, "s"),
            oracle.len(),
        );
        if !(got >= 0.98 * best) {
            eprintln!(
                "FAIL: pruned winner {} = {:.3e} is more than 2% below the unpruned \
                 optimum {:.3e}",
                points[0].spec, got, best
            );
            failed = true;
        }
        if warm.hits == 0 || warm.misses > 0 {
            eprintln!(
                "FAIL: re-sweep expected pure memo hits, saw {} hits / {} misses",
                warm.hits, warm.misses
            );
            failed = true;
        }
        for (a, b) in points.iter().zip(again.iter()) {
            if a.spec != b.spec || a.objective.to_bits() != b.objective.to_bits() {
                eprintln!("FAIL: memoized re-sweep diverged on {}", a.spec);
                failed = true;
                break;
            }
        }
        if !failed {
            println!("oracle checks passed: winner within 2%, re-sweep fully memoized");
        }
    }
    if failed {
        3
    } else {
        0
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let artifacts = args.get_or("artifacts", "artifacts");
    let requests = args.get_parsed("requests", 8usize);
    let steps = args.get_parsed("steps", 25usize);
    let mut config = EngineConfig::new(artifacts);
    config.quantized = !args.flag("fp32");
    config.policy.max_batch = args.get_parsed("batch", 4usize);
    let fleet = match fleet_from_args(args, 1) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 2;
        }
    };
    let policy = match policy_from_args(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    // Load-model flags: serve's requests come from the admission queue
    // (and drained mode defers with an unbounded backlog), so the
    // arrival-process and backlog knobs belong to the `cluster`
    // subcommand — accepting them here would silently do nothing.
    for flag in ["arrival", "clients", "gap-us", "backlog", "faults", "faults-file"] {
        if args.get(flag).is_some() {
            eprintln!(
                "error: --{flag} only applies to the artifact-free `cluster` subcommand; \
                 serve's requests come from the admission queue and drained mode always \
                 defers overload to an unbounded backlog"
            );
            return 2;
        }
    }
    config.slo_ms = match args.get("slo-ms") {
        Some(spec) => match parse_slo_spec(spec) {
            // EngineConfig carries milliseconds; the parser returns s.
            Ok(slos_s) => slos_s.into_iter().map(|s| s * 1e3).collect(),
            Err(e) => {
                eprintln!("error: {e:#}");
                return 2;
            }
        },
        None => Vec::new(),
    };
    config.shed_late = args.flag("shed-late");
    if config.shed_late && config.slo_ms.is_empty() {
        eprintln!("error: --shed-late needs deadlines to shed against; add --slo-ms MS[,MS...]");
        return 2;
    }
    // With no explicit fleet (and no explicit --capacity) the device
    // capacity tracks the batch knob, as it always has on this
    // subcommand; an explicit --capacity wins over that aliasing.
    let explicit_fleet = args.get("fleet").is_some() || args.get("fleet-file").is_some();
    let alias_capacity = !explicit_fleet && args.get("capacity").is_none();
    config.cluster = if alias_capacity {
        fleet.capacity(config.policy.max_batch)
    } else {
        fleet
    }
    .policy(policy);
    // The single-device run-to-completion loop ignores the cluster
    // profile entirely (it batches via --batch), so fleet-path-only
    // knobs that would be silently dropped there are loud errors.
    if !config.cluster.needs_fleet_scheduler() {
        if explicit_fleet {
            eprintln!(
                "error: this fleet spec resolves to a single default-profile device, which \
                 runs the single-device loop and would ignore the spec's queue shape; add \
                 more devices, reuse, or a custom arch — or drop --fleet/--fleet-file"
            );
            return 2;
        }
        if args.get("capacity").is_some() || args.get("max-queue").is_some() {
            eprintln!(
                "error: --capacity/--max-queue only apply to the fleet path; use --batch \
                 for the single-device loop, or add --devices N / --fleet"
            );
            return 2;
        }
        if !config.slo_ms.is_empty() {
            eprintln!(
                "error: --slo-ms/--shed-late only apply to the fleet path (the \
                 single-device loop has no deadline model); add --devices N / --fleet"
            );
            return 2;
        }
    }
    let mut coord = match Coordinator::open(config) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e:#}\nhint: run `make artifacts` first");
            return 1;
        }
    };
    println!("platform: {}", coord.platform());
    for i in 0..requests {
        coord.submit(1000 + i as u64, SamplerKind::Ddim { steps });
    }
    match coord.run_until_drained() {
        Ok(results) => {
            println!("served {} generations", results.len());
            let mut report = coord.metrics.to_json();
            if coord.fleet_metrics.is_some() {
                // Fleet drains record per-request latencies on the
                // simulated device clocks; wall_s stays host time.
                report = report.set("latency_clock_domain", "simulated-device");
            }
            println!("{}", report.to_string_pretty());
            if let Some(fleet) = &coord.fleet_metrics {
                println!("fleet (simulated clocks):");
                println!("{}", fleet.to_json().to_string_pretty());
            }
            0
        }
        Err(e) => {
            eprintln!("serving failed: {e:#}");
            1
        }
    }
}

fn cmd_cluster(args: &Args) -> i32 {
    let config = match fleet_from_args(args, 4) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 2;
        }
    };
    let config = match policy_from_args(args) {
        Ok(p) => config.policy(p),
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let config = config
        .backlog(args.get_parsed("backlog", 0usize))
        .shed_late(args.flag("shed-late"));
    let faults_spec = args.get("faults");
    let faults_file = args.get("faults-file");
    if faults_spec.is_some() && faults_file.is_some() {
        eprintln!("error: --faults and --faults-file are mutually exclusive");
        return 2;
    }
    let plan = match (faults_spec, faults_file) {
        (Some(spec), None) => match parse_fault_spec(spec, config.device_count()) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("error: {e:#}");
                return 2;
            }
        },
        (None, Some(path)) => {
            let parsed = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("--faults-file {path}: {e}"))
                .and_then(|text| parse_faults_json(&text));
            match parsed {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("error: {e:#}");
                    return 2;
                }
            }
        }
        _ => FaultPlan::default(),
    };
    if args.flag("no-migration") && faults_spec.is_none() && faults_file.is_none() {
        eprintln!(
            "error: --no-migration only changes behaviour under device churn; \
             add --faults/--faults-file"
        );
        return 2;
    }
    // The `resilience:` summary reports fault handling, so it only
    // prints when the plan actually touches a device in this fleet
    // (events aimed beyond the fleet are ignored by both cores).
    let churn = plan.sorted().iter().any(|e| e.device < config.device_count());
    let mut config = config.faults(plan).migration(!args.flag("no-migration"));
    let hedge = match (args.get("hedge-ms"), args.get("hedge-q")) {
        (Some(_), Some(_)) => {
            eprintln!("error: --hedge-ms and --hedge-q are mutually exclusive");
            return 2;
        }
        (Some(ms), None) => match ms.parse::<f64>() {
            Ok(v) if v > 0.0 && v.is_finite() => Some(HedgePolicy::fixed(v * 1e-3)),
            _ => {
                eprintln!("error: --hedge-ms {ms}: expected a finite threshold > 0 (milliseconds)");
                return 2;
            }
        },
        (None, Some(q)) => match q.parse::<f64>() {
            Ok(v) if v > 0.0 && v < 1.0 => Some(HedgePolicy::quantile(v)),
            _ => {
                eprintln!("error: --hedge-q {q}: expected a quantile in (0, 1)");
                return 2;
            }
        },
        (None, None) => None,
    };
    let brownout = match args.get("brownout").map(parse_brownout_spec).transpose() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("error: --brownout {e:#}");
            return 2;
        }
    };
    let retry = match args.get("retry").map(parse_retry_spec).transpose() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: --retry {e:#}");
            return 2;
        }
    };
    if let Some(policy) = hedge {
        config = config.hedge(policy);
    }
    if let Some(b) = brownout {
        config = config.brownout(b);
    }
    // Shard validation itself lives in `Cluster::new` (via `ShardMap`),
    // so `--shards 9` on an 8-device fleet fails loudly there; only the
    // `auto` keyword and plain parse errors are handled here.
    let shards = match args.get("shards") {
        None => 1,
        Some("auto") => ShardMap::auto(config.device_count()),
        Some(s) => match s.parse::<usize>() {
            Ok(n) => n,
            Err(_) => {
                eprintln!("error: --shards {s}: expected a positive integer or `auto`");
                return 2;
            }
        },
    };
    config = config.with_shards(shards);
    let requests = args.get_parsed("requests", 32usize);
    let steps = args.get_parsed("steps", 25usize);
    if steps > 1000 {
        eprintln!("--steps {steps} exceeds the T=1000 schedule; generations run 1000 steps");
    }
    let seed = args.get_parsed("seed", 1u64);
    let (source, slos_s) =
        match request_source_from_args(args, requests, seed, SamplerKind::Ddim { steps }) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("error: {e:#}");
                return 2;
            }
        };
    if brownout.is_some() && slos_s.is_empty() {
        eprintln!("error: --brownout adapts to SLO attainment; add --slo-ms MS[,MS...]");
        return 2;
    }
    let source = match retry {
        Some(policy) => source.with_retry(policy, seed),
        None => source,
    };

    // Pricing (per-profile accelerator cost models built by
    // `Cluster::simulated`) and the serve loop are timed separately so
    // events/s reflects only the scheduler hot path.
    let pricing_t0 = std::time::Instant::now();
    let mut cluster = match Cluster::simulated(config) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: invalid fleet: {e:#}");
            return 2;
        }
    };
    let pricing_s = pricing_t0.elapsed().as_secs_f64();
    let trace_path = args.get("trace").map(str::to_string);
    if trace_path.is_some() {
        cluster.set_trace(TraceSink::new());
    }
    let config = cluster.config.clone();
    let host_t0 = std::time::Instant::now();
    let outcome = match cluster.serve_source(source, &mut SimExecutor) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("cluster serving failed: {e:#}");
            return 1;
        }
    };
    let host_s = host_t0.elapsed().as_secs_f64();

    let m = &outcome.metrics;
    println!(
        "{} devices, {} profile(s) ({} policy, {} routing): served {}/{} requests, {} rejected",
        config.device_count(),
        config.fleet.len(),
        config.policy.name(),
        if config.cost_aware { "cost-aware" } else { "occupancy-only" },
        outcome.results.len(),
        requests,
        outcome.rejected.len()
    );
    if config.fleet.len() > 1 {
        for (pi, (profile, count)) in config.fleet.iter().enumerate() {
            println!("  profile {pi}: {profile} x{count}");
        }
    }
    let mut table =
        Table::new(&["device", "prof", "steps", "samples", "busy", "util", "GOPS", "EPB"]);
    for d in &m.devices {
        table.row(&[
            d.id.to_string(),
            d.profile.to_string(),
            d.steps_executed.to_string(),
            d.samples_completed.to_string(),
            fmt_si(d.busy_s, "s"),
            format!("{:.0}%", 100.0 * d.utilization(m.makespan_s)),
            format!("{:.1}", d.gops()),
            fmt_si(d.epb(), "J/bit"),
        ]);
    }
    print!("{}", table.render());
    if config.fleet.len() > 1 {
        for g in m.per_profile() {
            println!(
                "profile {}: {} devices, {:.1} samples/s, util {:.0}%, EPB {}",
                g.profile,
                g.devices,
                g.throughput_samples_per_s(m.makespan_s),
                100.0 * g.utilization(m.makespan_s),
                fmt_si(g.epb(), "J/bit"),
            );
        }
    }
    println!(
        "fleet: {:.1} samples/s (simulated), p50 {} p99 {}, {:.1} GOPS, EPB {}",
        m.throughput_samples_per_s(),
        fmt_si(m.latency_p50_s(), "s"),
        fmt_si(m.latency_p99_s(), "s"),
        m.fleet_gops(),
        fmt_si(m.fleet_epb(), "J/bit"),
    );
    if !slos_s.is_empty() {
        println!(
            "slo: goodput {:.1} samples/s, attainment {:.1}% of offered, {} shed{}",
            m.goodput_samples_per_s(),
            100.0 * m.slo_attainment(),
            m.rejected,
            if config.shed_late { " (deadline-aware)" } else { "" },
        );
        for c in &m.classes {
            println!(
                "  class {} (slo {}): {} served, {} shed, attainment {:.1}%, p50 {} p99 {}",
                c.class,
                fmt_si(slos_s.get(c.class as usize).copied().unwrap_or(0.0), "s"),
                c.completed(),
                c.shed,
                100.0 * c.attainment(),
                fmt_si(c.latency_p50_s(), "s"),
                fmt_si(c.latency_p99_s(), "s"),
            );
        }
    }
    if churn {
        println!(
            "resilience: {} interrupted, {} migrated, {} requeued, {} lost, downtime {}{}",
            m.interrupted(),
            m.migrated(),
            m.retried(),
            m.lost(),
            fmt_si(m.downtime_s(), "s"),
            if config.migration { "" } else { " (migration disabled)" },
        );
    }
    if retry.is_some() || hedge.is_some() || brownout.is_some() {
        println!(
            "recovery: {} retried, {} hedged, {} cancelled, {} degraded admissions",
            m.retries(),
            m.hedged(),
            m.cancelled(),
            m.degraded(),
        );
    }
    println!(
        "scheduler: {} events in {} serving host time ({:.0} events/s; {} shard(s); pricing {})",
        m.sched_events,
        fmt_si(host_s, "s"),
        if host_s > 0.0 { m.sched_events as f64 / host_s } else { 0.0 },
        config.shards,
        fmt_si(pricing_s, "s"),
    );
    if let Some(path) = &trace_path {
        let sink = cluster.take_trace().expect("trace sink was attached above");
        let write = std::fs::File::create(path).and_then(|mut f| sink.write_jsonl(&mut f));
        match write {
            Ok(()) => println!("wrote {} trace events to {path}", sink.len()),
            Err(e) => {
                eprintln!("error: --trace {path}: {e}");
                return 1;
            }
        }
    }
    if config.any_reuse() {
        println!(
            "reuse: {} cache-hit / {} full sample-steps ({:.0}% hit rate)",
            m.reuse_hits(),
            m.reuse_misses(),
            100.0 * m.reuse_hit_rate(),
        );
    }
    if std::fs::create_dir_all("artifacts").is_ok()
        && std::fs::write("artifacts/cluster_report.json", m.to_json().to_string_pretty()).is_ok()
    {
        println!("wrote artifacts/cluster_report.json");
    }
    0
}

/// `trace replay FILE [FILE2] [--expect report.json]`: rebuild a run
/// from its flight-recorder trace. One file prints the replayed
/// summary (and, with `--expect`, verifies it against a live fleet
/// report bit-for-bit); two files diff the scheduler decisions.
fn cmd_trace(args: &Args) -> i32 {
    const USAGE: &str = "usage: trace replay FILE [FILE2] [--expect report.json]";
    if args.positional(1) != Some("replay") {
        eprintln!("{USAGE}");
        return 2;
    }
    let Some(path) = args.positional(2) else {
        eprintln!("{USAGE}");
        return 2;
    };
    let read_trace = |p: &str| -> Result<Vec<TraceEvent>, String> {
        let text = std::fs::read_to_string(p).map_err(|e| format!("{p}: {e}"))?;
        parse_jsonl_versioned(&text).map_err(|e| format!("{p}: {e}"))
    };
    let a = match read_trace(path) {
        Ok(events) => events,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    if let Some(path_b) = args.positional(3) {
        let b = match read_trace(path_b) {
            Ok(events) => events,
            Err(e) => {
                eprintln!("error: {e}");
                return 2;
            }
        };
        let d = diff(&a, &b);
        if d.identical() {
            println!("traces identical: {} events", a.len());
            return 0;
        }
        if let Some((i, ea, eb)) = &d.first_divergence {
            println!("first divergence at event {i}:");
            println!("  {path}: {ea}");
            println!("  {path_b}: {eb}");
        }
        if d.route_deltas.is_empty() {
            println!("routing: per-device admission counts agree");
        } else {
            for (dev, ra, rb) in &d.route_deltas {
                println!("routing: device {dev} admitted {ra} vs {rb}");
            }
        }
        return 1;
    }
    let rep = replay(&a);
    println!("replayed {} events from {path}", a.len());
    println!("{}", replay_summary(&rep).to_string_pretty());
    if let Some(expect) = args.get("expect") {
        let report = match std::fs::read_to_string(expect)
            .map_err(|e| format!("{expect}: {e}"))
            .and_then(|text| Json::parse(&text).map_err(|e| format!("{expect}: {e}")))
        {
            Ok(j) => j,
            Err(e) => {
                eprintln!("error: --expect {e}");
                return 2;
            }
        };
        let bad = check_against_report(&rep, &report);
        if bad.is_empty() {
            println!("replay matches {expect} exactly");
        } else {
            eprintln!("replay diverges from {expect} on: {}", bad.join(", "));
            return 1;
        }
    }
    0
}

fn cmd_devices() -> i32 {
    let p = DeviceParams::paper();
    let mut t = Table::new(&["device", "latency", "power"]);
    let rows: Vec<(&str, f64, f64)> = vec![
        ("EO tuning", p.eo_tuning_latency_s, p.eo_tuning_power_w),
        ("TO tuning (per FSR)", p.to_tuning_latency_s, p.to_tuning_power_w_per_fsr),
        ("VCSEL", p.vcsel_latency_s, p.vcsel_power_w),
        ("Photodetector", p.pd_latency_s, p.pd_power_w),
        ("SOA", p.soa_latency_s, p.soa_power_w),
        ("DAC (8-bit)", p.dac_latency_s, p.dac_power_w),
        ("ADC (8-bit)", p.adc_latency_s, p.adc_power_w),
        ("Comparator", p.comparator_latency_s, p.comparator_power_w),
        ("Subtractor", p.subtractor_latency_s, p.subtractor_power_w),
        ("LUT", p.lut_latency_s, p.lut_power_w),
    ];
    for (name, lat, pow) in rows {
        t.row(&[name.to_string(), fmt_si(lat, "s"), fmt_si(pow, "W")]);
    }
    print!("{}", t.render());
    0
}
