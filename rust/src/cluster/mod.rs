//! Multi-accelerator sharded serving with continuous step-level batching.
//!
//! A fleet of N simulated DiffLight devices — each one a
//! [`crate::sim::Simulator`]-priced compute tile — behind a step-level
//! scheduler. Where the single-device coordinator runs every batch to
//! completion, the cluster interleaves requests at **denoise-step
//! granularity**: devices own step queues, requests join and leave
//! batches between UNet calls, and a shard router spreads load with
//! admission control and backpressure.
//!
//! * [`device`] — device handle: batch-slot capacity, simulated clock,
//!   per-step cost from [`crate::arch::cost`].
//! * [`router`] — shard policies: round-robin, least-loaded,
//!   sampler-signature affinity; both the stateless snapshot router and
//!   the incrementally maintained O(log N) [`RouterIndex`].
//! * [`scheduler`] — the heap-based discrete-event core (O(log N) per
//!   event: completion heap, router index, dirty-set kicks, zero-alloc
//!   fused-step buffers) over [`crate::util::threadpool`].
//! * [`reference`] — the retained O(events × devices) loop, the
//!   bit-identity oracle and scaling baseline for the event core.
//! * [`metrics`] — per-device + fleet p50/p99 latency, EPB and GOPS
//!   roll-ups reusing [`crate::util::stats`].

pub mod device;
pub mod metrics;
pub mod reference;
pub mod router;
pub mod scheduler;

pub use device::{Device, DeviceId, ReuseSchedule};
pub use metrics::{DeviceMetrics, FleetMetrics};
pub use reference::ReferenceScheduler;
pub use router::{DeviceLoad, Router, RouterIndex, ShardPolicy};
pub use scheduler::{
    ClusterOutcome, ClusterRequest, ClusterResult, SimExecutor, StepExecutor, StepScheduler,
};

use crate::arch::cost::OptFlags;
use crate::coordinator::request::SamplerKind;
use crate::runtime::manifest::NoiseSchedule;
use crate::sim::Simulator;
use crate::util::rng::XorShift;
use crate::workload::ModelId;

/// Fleet shape and policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterConfig {
    /// Number of simulated DiffLight devices.
    pub devices: usize,
    /// Resident batch slots per device.
    pub capacity: usize,
    /// Admission-queue depth per device before backpressure.
    pub max_queue: usize,
    /// Fleet-level deferral backlog: requests that find every device
    /// full wait here and are re-routed at the next step boundary.
    /// `0` (the default) sheds immediately — live-serving backpressure;
    /// drained/offline callers raise it so nothing is dropped.
    pub max_backlog: usize,
    pub policy: ShardPolicy,
    /// Workload whose per-step cost prices the device clock.
    pub model: ModelId,
    pub opts: OptFlags,
    /// Marginal latency of each extra resident sample in a fused step,
    /// as a fraction of the single-sample step latency.
    pub batch_marginal: f64,
    /// DeepCache step reuse: run the full UNet every `reuse_interval`
    /// fused steps and the shallow cache-hit path in between. `1` (the
    /// default) disables reuse and reproduces the pre-reuse schedule
    /// exactly.
    pub reuse_interval: usize,
    /// Cost of a shallow cache-hit step relative to a full step.
    pub reuse_shallow_frac: f64,
    /// Let idle, empty devices steal queued requests from the
    /// most-loaded busy device at step boundaries.
    pub work_stealing: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            devices: 1,
            capacity: 4,
            max_queue: 64,
            max_backlog: 0,
            policy: ShardPolicy::default(),
            model: ModelId::DdpmCifar10,
            opts: OptFlags::ALL,
            batch_marginal: 0.25,
            reuse_interval: 1,
            reuse_shallow_frac: 0.25,
            work_stealing: true,
        }
    }
}

impl ClusterConfig {
    pub fn with_devices(devices: usize) -> Self {
        Self { devices, ..Self::default() }
    }

    /// Enable DeepCache step reuse at interval `k` (1 = off).
    pub fn with_reuse(mut self, k: usize) -> Self {
        self.reuse_interval = k.max(1);
        self
    }
}

/// Facade tying the cost model to the scheduler: prices one denoise step
/// on the paper-optimal accelerator and builds the fleet.
pub struct Cluster {
    pub config: ClusterConfig,
    scheduler: StepScheduler,
}

impl Cluster {
    /// Build a fleet, pricing the per-step device cost from the
    /// transaction-level simulator for `config.model` under `config.opts`
    /// (through the shared cost cache and the interned trace store, so
    /// repeated fleet constructions never re-price or rebuild the trace).
    pub fn new(config: ClusterConfig, schedule: NoiseSchedule, elems: usize) -> Self {
        let sim = Simulator::paper_cached();
        let step_cost = sim.model_step_cost(config.model, config.opts);
        let bit_width = sim.params.bit_width;
        Self {
            scheduler: StepScheduler::new(&config, step_cost, schedule, elems, bit_width),
            config,
        }
    }

    /// Pure-simulation fleet over a locally rebuilt noise schedule (no
    /// artifacts required) — what the benches and the `cluster` CLI use.
    pub fn simulated(config: ClusterConfig) -> Self {
        // T=1000 (the DDPM convention) so DDIM sub-schedules up to 1000
        // steps run unclamped; 16×16×1 sample geometry matches the AOT
        // pipeline's default.
        Self::new(config, NoiseSchedule::linear(1000), 256)
    }

    /// Serve a workload to completion through `executor`.
    pub fn serve(
        &mut self,
        requests: Vec<ClusterRequest>,
        executor: &mut dyn StepExecutor,
    ) -> crate::Result<ClusterOutcome> {
        self.scheduler.serve(requests, executor)
    }

    pub fn device_count(&self) -> usize {
        self.scheduler.device_count()
    }
}

/// Synthetic open-loop workload: `n` requests with exponential
/// inter-arrival gaps (mean `mean_gap_s`), deterministic in `seed`.
pub fn synthetic_workload(
    n: usize,
    seed: u64,
    sampler: SamplerKind,
    mean_gap_s: f64,
) -> Vec<ClusterRequest> {
    let mut rng = XorShift::new(seed);
    let mut at = 0.0f64;
    (0..n)
        .map(|i| {
            let req = ClusterRequest::new(i as u64, seed.wrapping_mul(1000) + i as u64, sampler, at);
            // Exponential gap; max(1e-12) guards ln(0).
            at += -mean_gap_s * (1.0 - rng.next_f64()).max(1e-12).ln();
            req
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simulated_cluster_serves() {
        let mut c = Cluster::simulated(ClusterConfig::with_devices(2));
        assert_eq!(c.device_count(), 2);
        let reqs = synthetic_workload(6, 3, SamplerKind::Ddim { steps: 5 }, 0.0);
        let out = c.serve(reqs, &mut SimExecutor).unwrap();
        assert_eq!(out.results.len(), 6);
        assert!(out.metrics.makespan_s > 0.0);
        assert!(out.metrics.fleet_gops() > 0.0);
    }

    #[test]
    fn workload_is_deterministic_and_ordered() {
        let a = synthetic_workload(20, 9, SamplerKind::Ddpm, 1e-3);
        let b = synthetic_workload(20, 9, SamplerKind::Ddpm, 1e-3);
        assert_eq!(a.len(), 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert!((x.arrival_s - y.arrival_s).abs() < 1e-15);
        }
        assert!(a.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        assert_eq!(a[0].arrival_s, 0.0);
    }

    #[test]
    fn zero_gap_workload_is_a_burst() {
        let w = synthetic_workload(5, 1, SamplerKind::Ddpm, 0.0);
        assert!(w.iter().all(|r| r.arrival_s == 0.0));
    }
}
