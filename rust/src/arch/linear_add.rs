//! Linear & add block (paper §IV.B.4, Fig. 7).
//!
//! The MHA unit's single output block: a linear path of two `M × L` MR
//! bank arrays (activations, weights) detected by BPDs, then an add path
//! where the linear output and the residual each drive a VCSEL at the
//! same wavelength λ₀ and undergo coherent summation into a PD.

use crate::devices::DeviceParams;

use super::bank_array::{BankArrayModel, Gemm};
use super::cost::{Cost, OptFlags};

/// The linear & add block.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearAddBlock {
    pub array: BankArrayModel,
}

impl LinearAddBlock {
    pub fn new(m: usize, l: usize, wavelengths: usize) -> Self {
        Self { array: BankArrayModel::new(m, l, wavelengths) }
    }

    /// Price the MHA output projection: concat(heads) `[seq × h·d_v]`
    /// times `W_O [h·d_v × d_model]`, followed by the coherent residual
    /// add over `seq × d_model` elements.
    pub fn cost(
        &self,
        seq: usize,
        concat_dim: usize,
        d_model: usize,
        p: &DeviceParams,
        opts: OptFlags,
    ) -> Cost {
        if seq == 0 || concat_dim == 0 || d_model == 0 {
            return Cost::ZERO;
        }
        let linear = self
            .array
            .gemm_cost(&Gemm::dense(seq, concat_dim, d_model), p, opts);
        let add = self.coherent_add_cost(seq * d_model, p);
        linear.then(add)
    }

    /// Coherent add: two VCSELs at λ₀ per element pair, one PD detection.
    /// Elements stream through the block's `M` row waveguides.
    pub fn coherent_add_cost(&self, elements: usize, p: &DeviceParams) -> Cost {
        if elements == 0 {
            return Cost::ZERO;
        }
        let lanes = self.array.rows.max(1);
        let batches = elements.div_ceil(lanes) as u64;
        let per_batch = p.vcsel_latency_s + p.pd_latency_s;
        let per_elem =
            2.0 * p.vcsel_power_w * p.vcsel_latency_s + p.pd_power_w * p.pd_latency_s;
        Cost {
            latency_s: batches as f64 * per_batch,
            energy_j: elements as f64 * per_elem,
            ops: elements as u64,
            passes: batches,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block() -> LinearAddBlock {
        LinearAddBlock::new(3, 6, 36)
    }

    fn p() -> DeviceParams {
        DeviceParams::paper()
    }

    #[test]
    fn cost_includes_linear_and_add_ops() {
        let c = block().cost(64, 96, 128, &p(), OptFlags::BASELINE);
        let expected = 2 * (64 * 96 * 128) as u64 + (64 * 128) as u64;
        assert_eq!(c.ops, expected);
    }

    #[test]
    fn zero_dims_free() {
        let b = block();
        assert_eq!(b.cost(0, 96, 128, &p(), OptFlags::ALL), Cost::ZERO);
        assert_eq!(b.cost(64, 0, 128, &p(), OptFlags::ALL), Cost::ZERO);
        assert_eq!(b.coherent_add_cost(0, &p()), Cost::ZERO);
    }

    #[test]
    fn add_is_small_next_to_linear() {
        let b = block();
        let total = b.cost(64, 96, 128, &p(), OptFlags::BASELINE);
        let add = b.coherent_add_cost(64 * 128, &p());
        assert!(add.energy_j < 0.05 * total.energy_j);
    }

    #[test]
    fn pipelining_helps_linear_path() {
        let b = block();
        let base = b.cost(64, 96, 128, &p(), OptFlags::BASELINE);
        let piped = b.cost(64, 96, 128, &p(), OptFlags::PIPELINED);
        assert!(piped.latency_s < base.latency_s);
    }
}
