//! # DiffLight — a silicon-photonics accelerator for diffusion models
//!
//! Full-stack reproduction of *"Accelerating Diffusion Models for Generative
//! AI Applications with Silicon Photonics"* (Suresh, Afifi, Pasricha,
//! CS.AR 2026).
//!
//! The crate is organised as the paper's system plus every substrate it
//! depends on:
//!
//! * [`devices`] — optoelectronic device library: microring resonators
//!   (MRs), MR bank arrays, VCSELs, photodetectors / balanced
//!   photodetectors, SOAs, DAC/ADC, hybrid EO/TO tuning with TED, the
//!   photonic loss budget and laser-power solver (Table II constants).
//! * [`arch`] — the DiffLight block architecture: convolution &
//!   normalization blocks, the SOA activation block, attention head
//!   blocks, the linear & add block, composed into Residual and MHA
//!   units under an electronic control unit (ECU). Parameterised by
//!   `[Y, N, K, H, L, M]` (paper §IV.B, optimum `[4,12,3,6,6,3]`).
//! * [`workload`] — the diffusion-model workload zoo (DDPM/CIFAR-10,
//!   LDM/LSUN-Churches, LDM/LSUN-Beds, Stable Diffusion v1-4) expressed
//!   as exact layer-level traces, with im2col lowering and the
//!   transposed-convolution zero-insertion sparsity analysis.
//! * [`sim`] — the transaction-level performance/energy simulator with
//!   the paper's three dataflow optimizations (sparsity-aware dataflow,
//!   inter/intra-block pipelining, DAC sharing) as toggles.
//! * [`baselines`] — analytical models of the comparison platforms:
//!   CPU, GPU, DeepCache, two FPGA accelerators, and PACE.
//! * [`dse`] — design-space exploration over `[Y, N, K, H, L, M]`.
//! * [`quant`] — the W8A8 symmetric quantization model shared with the
//!   compiled compute path.
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas UNet
//!   (HLO text → compile → execute); Python never runs at serve time.
//! * [`coordinator`] — the serving layer: request router, dynamic
//!   batcher and denoise-step scheduler driving [`runtime`].
//! * [`cluster`] — multi-accelerator sharded serving: a fleet of
//!   simulated DiffLight devices — homogeneous or heterogeneous, each
//!   priced from its own per-device `[Y,N,K,H,L,M]@λ` profile — behind
//!   a step-level continuous-batching scheduler, with round-robin /
//!   cost-aware least-loaded / sampler-affinity shard routing,
//!   admission control, and per-device + per-profile + fleet metric
//!   roll-ups.
//! * [`util`] — infrastructure hand-rolled for the offline build: CLI
//!   parsing, deterministic PRNG, JSON writer, thread pool, and a small
//!   property-testing harness.
//!
//! See `DESIGN.md` for the system inventory and per-experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod arch;
pub mod baselines;
pub mod cluster;
pub mod coordinator;
pub mod devices;
pub mod dse;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// The paper's optimal DiffLight configuration `[Y, N, K, H, L, M]`
/// (§V: "the exploration yielded ... [4,12,3,6,6,3]").
pub const PAPER_OPTIMAL_CONFIG: [usize; 6] = [4, 12, 3, 6, 6, 3];

/// Maximum number of MRs sharing one waveguide while staying error-free
/// (§V, Lumerical-derived design rule).
pub const MAX_MRS_PER_WAVEGUIDE: usize = 36;
