//! The DiffLight block architecture (paper §IV, Fig. 3).
//!
//! A DiffLight instance is a Residual unit (`Y` convolution & normalization
//! blocks + one activation block) and an MHA unit (`H` attention-head
//! blocks + one linear & add block), coordinated by an ECU. Blocks are
//! parameterised by the architectural vector `[Y, N, K, H, L, M]`; the
//! paper's design-space exploration selects `[4, 12, 3, 6, 6, 3]`.
//!
//! Each block exposes a *cost model*: given an operation's dimensions and
//! the active dataflow optimizations it returns latency, energy, and
//! op counts. The [`crate::sim`] engine composes these per layer and per
//! timestep; [`crate::dse`] sweeps the architectural vector.

pub mod activation;
pub mod attention;
pub mod bank_array;
pub mod config;
pub mod conv_norm;
pub mod cost;
pub mod linear_add;
pub mod units;

pub use config::ArchConfig;
pub use cost::{Cost, OptFlags};
