//! The architectural design space: per-die vectors ([`DesignSpace`]) and
//! fleet compositions over a die menu ([`FleetSpace`]).

use crate::arch::ArchConfig;
use crate::cluster::DeviceProfile;

/// Candidate ranges per architectural parameter.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignSpace {
    pub y: Vec<usize>,
    pub n: Vec<usize>,
    pub k: Vec<usize>,
    pub h: Vec<usize>,
    pub l: Vec<usize>,
    pub m: Vec<usize>,
    pub wavelengths: usize,
    /// Silicon budget: maximum total MR count a candidate may use.
    pub max_total_mrs: usize,
}

impl DesignSpace {
    /// The sweep used by the paper-reproduction bench: a neighbourhood
    /// around plausible block counts/geometries, with the silicon budget
    /// set to the paper configuration's footprint (+5% slack).
    pub fn paper() -> Self {
        let budget = ArchConfig::paper_optimal().total_mrs();
        Self {
            y: vec![1, 2, 4, 6, 8],
            n: vec![4, 8, 12, 16, 24],
            k: vec![1, 2, 3, 4, 6],
            h: vec![2, 4, 6, 8],
            l: vec![2, 4, 6, 8, 12],
            m: vec![1, 2, 3, 4, 6],
            wavelengths: 36,
            max_total_mrs: budget + budget / 20,
        }
    }

    /// Enumerate all in-budget candidates.
    pub fn candidates(&self) -> Vec<ArchConfig> {
        let mut out = Vec::new();
        for &y in &self.y {
            for &n in &self.n {
                for &k in &self.k {
                    for &h in &self.h {
                        for &l in &self.l {
                            for &m in &self.m {
                                let c = ArchConfig::from_vector(
                                    [y, n, k, h, l, m],
                                    self.wavelengths,
                                );
                                if c.total_mrs() <= self.max_total_mrs {
                                    out.push(c);
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Total unconstrained size of the grid.
    pub fn grid_size(&self) -> usize {
        self.y.len() * self.n.len() * self.k.len() * self.h.len() * self.l.len() * self.m.len()
    }
}

/// The fleet-composition search space: a menu of candidate dies and a
/// set of per-die counts, swept as a cartesian product under a total-MR
/// silicon budget. A candidate is a `--fleet`-style spec — profile
/// groups × counts — fed to [`crate::cluster::Cluster::from_fleet`].
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpace {
    /// Candidate dies. Kept small and architecturally diverse: the sweep
    /// cost is exponential in menu size (`counts^menu` fleets).
    pub menu: Vec<DeviceProfile>,
    /// Per-die instance counts to try (0 = leave the die out).
    pub counts: Vec<usize>,
    /// Silicon budget: maximum total MR count across the whole fleet.
    pub max_total_mrs: usize,
}

impl FleetSpace {
    /// The MR footprint of one paper-optimal die — the natural budget
    /// unit for fleet sweeps (`--budget-dies` in the CLI).
    pub fn paper_die_mrs() -> usize {
        ArchConfig::paper_optimal().total_mrs()
    }

    /// The bench/CLI menu: three §V-rule-saturating dies around the
    /// paper optimum — a wide conv-heavy die (Y=8, H=8), the paper die
    /// itself, and a small low-area die (Y=2, H=3) with a shallower
    /// resident batch — swept over counts `{0, 1, 2, 4, 8}` under
    /// `budget_mrs` total silicon.
    pub fn paper(budget_mrs: usize) -> Self {
        let die = |v: [usize; 6]| DeviceProfile {
            arch: ArchConfig::from_vector(v, 36),
            ..DeviceProfile::default()
        };
        let small = DeviceProfile {
            arch: ArchConfig::from_vector([2, 12, 3, 3, 6, 3], 36),
            capacity: 2,
            ..DeviceProfile::default()
        };
        Self {
            menu: vec![die([8, 12, 3, 8, 6, 3]), die([4, 12, 3, 6, 6, 3]), small],
            counts: vec![0, 1, 2, 4, 8],
            max_total_mrs: budget_mrs,
        }
    }

    /// Total MR footprint of a fleet spec.
    pub fn fleet_mrs(fleet: &[(DeviceProfile, usize)]) -> usize {
        fleet.iter().map(|(p, n)| p.arch.total_mrs() * n).sum()
    }

    /// Enumerate all in-budget, non-empty fleet candidates. Each
    /// candidate lists only the menu dies with a non-zero count, in menu
    /// order (canonicalisation to a sorted key is the memo's job, not
    /// the enumerator's).
    pub fn candidates(&self) -> Vec<Vec<(DeviceProfile, usize)>> {
        let mut out = Vec::new();
        if self.menu.is_empty() || self.counts.is_empty() {
            return out;
        }
        let mut idx = vec![0usize; self.menu.len()];
        loop {
            let fleet: Vec<(DeviceProfile, usize)> = self
                .menu
                .iter()
                .zip(idx.iter())
                .map(|(p, &i)| (*p, self.counts[i]))
                .filter(|&(_, n)| n > 0)
                .collect();
            if !fleet.is_empty() && Self::fleet_mrs(&fleet) <= self.max_total_mrs {
                out.push(fleet);
            }
            // Odometer increment over indices into `self.counts`.
            let mut i = 0;
            loop {
                if i == idx.len() {
                    return out;
                }
                idx[i] += 1;
                if idx[i] < self.counts.len() {
                    break;
                }
                idx[i] = 0;
                i += 1;
            }
        }
    }

    /// Total unconstrained size of the grid (including the empty fleet).
    pub fn grid_size(&self) -> usize {
        self.counts.len().pow(self.menu.len() as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_space_contains_paper_config() {
        let s = DesignSpace::paper();
        let cands = s.candidates();
        assert!(
            cands.iter().any(|c| c.vector() == crate::PAPER_OPTIMAL_CONFIG),
            "paper optimum must be a candidate"
        );
    }

    #[test]
    fn budget_prunes_grid() {
        let s = DesignSpace::paper();
        assert!(s.candidates().len() < s.grid_size());
        assert!(!s.candidates().is_empty());
    }

    #[test]
    fn all_candidates_within_budget() {
        let s = DesignSpace::paper();
        assert!(s.candidates().iter().all(|c| c.total_mrs() <= s.max_total_mrs));
    }

    #[test]
    fn fleet_space_enumerates_nonempty_in_budget_fleets() {
        let s = FleetSpace::paper(8 * FleetSpace::paper_die_mrs());
        let cands = s.candidates();
        assert!(!cands.is_empty());
        assert!(cands.len() < s.grid_size(), "budget + empty-fleet skip must prune");
        for fleet in &cands {
            assert!(!fleet.is_empty());
            assert!(fleet.iter().all(|&(_, n)| n > 0), "zero-count groups must be dropped");
            assert!(FleetSpace::fleet_mrs(fleet) <= s.max_total_mrs);
        }
        // Every menu die validates against the paper design rules.
        let p = crate::devices::DeviceParams::paper();
        for die in &s.menu {
            die.validate(&p).expect("menu die must satisfy design rules");
        }
        // The homogeneous all-paper fleet (8x the default die) is in the space.
        let d = DeviceProfile::default();
        assert!(cands.iter().any(|f| f == &vec![(d, 8)]));
    }

    #[test]
    fn fleet_space_candidates_are_distinct() {
        let s = FleetSpace::paper(8 * FleetSpace::paper_die_mrs());
        let keys: std::collections::HashSet<String> = s
            .candidates()
            .iter()
            .map(|f| crate::cluster::fleet_spec_key(f))
            .collect();
        assert_eq!(keys.len(), s.candidates().len(), "no two candidates share a memo key");
    }

    #[test]
    fn tiny_budget_still_admits_the_small_die() {
        // One small die fits in a one-paper-die budget; the big die does not.
        let s = FleetSpace::paper(FleetSpace::paper_die_mrs());
        let cands = s.candidates();
        assert!(!cands.is_empty());
        let small = s.menu[2];
        assert!(cands.iter().any(|f| f == &vec![(small, 1)]));
        let big = s.menu[0];
        assert!(!cands.iter().any(|f| f.iter().any(|&(p, _)| p == big)));
    }
}
