"""AOT compile path: lower the L2 denoise step to HLO text artifacts.

Python runs ONCE, here. The Rust coordinator (`rust/src/runtime`) loads
``artifacts/*.hlo.txt`` through the PJRT C API and owns the request path.

Interchange format is **HLO text**, not serialized HloModuleProto: jax
≥ 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids (see
/opt/xla-example/README.md).

Artifacts written:

* ``model_w8a8_b{B}.hlo.txt`` — quantized (photonic-datapath) UNet step
  for each requested batch size;
* ``model_fp32_b1.hlo.txt``   — f32 reference step;
* ``manifest.json``           — shapes, UNet config, and the DDPM
  noise schedule the Rust sampler needs (betas/alphas/alpha_bars);
* weights come from ``artifacts/params.npz`` when `train.py` has run,
  else from a seeded random init (recorded in the manifest).

Usage: ``python -m compile.aot [--out-dir ../artifacts] [--batches 1,4]``
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring).

    CRITICAL: the default printer elides large constants as ``{...}``,
    which XLA's text *parser* silently reads back as zeros — the model
    weights would vanish. ``print_large_constants`` keeps them verbatim.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # xla_extension 0.5.1's text parser predates jax's newer metadata
    # attributes (source_end_line etc.) — keep metadata out of the text.
    opts.print_metadata = False
    text = comp.as_hlo_module().to_string(opts)
    assert "{...}" not in text, "elided constants survived printing"
    return text


def ddpm_schedule(timesteps: int):
    """Linear-β DDPM schedule (Ho et al.), as plain floats for JSON."""
    betas = np.linspace(1e-4, 0.02, timesteps, dtype=np.float64)
    alphas = 1.0 - betas
    alpha_bars = np.cumprod(alphas)
    return {
        "timesteps": timesteps,
        "betas": betas.tolist(),
        "alphas": alphas.tolist(),
        "alpha_bars": alpha_bars.tolist(),
    }


def load_or_init_params(cfg: M.UNetConfig, artifacts_dir: str):
    """Trained weights if available, else seeded random init."""
    path = os.path.join(artifacts_dir, "params.npz")
    if os.path.exists(path):
        flat = dict(np.load(path))
        params = unflatten_params(flat)
        return params, "trained"
    params = M.init_params(jax.random.PRNGKey(42), cfg)
    return params, "random-init(seed=42)"


def flatten_params(params, prefix=""):
    out = {}
    for k, v in params.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(flatten_params(v, key + "/"))
        else:
            out[key] = np.asarray(v)
    return out


def unflatten_params(flat):
    params = {}
    for key, v in flat.items():
        parts = key.split("/")
        d = params
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = jnp.asarray(v)
    return params


def lower_step(params, cfg: M.UNetConfig, batch: int, quantized: bool) -> str:
    """Lower one denoise step (weights folded in as constants)."""

    def step(x, t):
        return M.denoise_step(params, x, t, cfg, quantized=quantized, use_pallas=True)

    x_spec = jax.ShapeDtypeStruct(
        (batch, cfg.image_size, cfg.image_size, cfg.in_channels), jnp.float32
    )
    t_spec = jax.ShapeDtypeStruct((batch,), jnp.float32)
    return to_hlo_text(jax.jit(step).lower(x_spec, t_spec))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--batches", default="1,4", help="comma-separated batch sizes for the W8A8 artifact")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    cfg = M.UNetConfig()
    params, provenance = load_or_init_params(cfg, out_dir)
    batches = [int(b) for b in args.batches.split(",") if b]

    artifacts = {}
    for b in batches:
        name = f"model_w8a8_b{b}.hlo.txt"
        text = lower_step(params, cfg, b, quantized=True)
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        artifacts[name] = {"batch": b, "quantized": True, "chars": len(text)}
        print(f"wrote {name} ({len(text)} chars)")

    name = "model_fp32_b1.hlo.txt"
    text = lower_step(params, cfg, 1, quantized=False)
    with open(os.path.join(out_dir, name), "w") as f:
        f.write(text)
    artifacts[name] = {"batch": 1, "quantized": False, "chars": len(text)}
    print(f"wrote {name} ({len(text)} chars)")

    manifest = {
        "config": {
            "image_size": cfg.image_size,
            "in_channels": cfg.in_channels,
            "model_channels": cfg.model_channels,
            "channel_mult": list(cfg.channel_mult),
            "num_res_blocks": cfg.num_res_blocks,
            "num_heads": cfg.num_heads,
            "groups": cfg.groups,
        },
        "weights": provenance,
        "schedule": ddpm_schedule(cfg.timesteps),
        "artifacts": artifacts,
        "input_layout": "x: (B,H,W,C) f32; t: (B,) f32; output tuple: (eps,)",
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(artifacts)} artifacts, weights={provenance})")


if __name__ == "__main__":
    main()
