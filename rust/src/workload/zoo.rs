//! The evaluated diffusion-model zoo (paper Table I).
//!
//! | Model            | Dataset       | Parameters | IS drop (W8A8) |
//! |------------------|---------------|------------|----------------|
//! | DDPM             | CIFAR-10      | 61.9 M     | 0.44 %         |
//! | LDM 1            | LSUN-Churches | 294.96 M   | 0.43 %         |
//! | LDM 2            | LSUN-Beds     | 274.05 M   | 5.26 %         |
//! | Stable Diffusion | sd-v1-4       | 859.52 M   | 6.66 %         |
//!
//! Each entry carries the UNet hyper-parameters that reproduce the
//! published parameter count (asserted in tests), the sampling schedule,
//! and the latent/pixel geometry. The *traces* built from these configs
//! are what every simulator experiment consumes.

use super::layers::{graph_stats, GraphStats, LayerInstance};
use super::unet::{build_unet, UNetConfig};

/// Identifier for the four evaluated models.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelId {
    DdpmCifar10,
    LdmChurches,
    LdmBeds,
    StableDiffusion,
}

impl ModelId {
    pub const ALL: [ModelId; 4] = [
        ModelId::DdpmCifar10,
        ModelId::LdmChurches,
        ModelId::LdmBeds,
        ModelId::StableDiffusion,
    ];

    /// Dense position of this model in [`ModelId::ALL`] (used by the
    /// interned-trace store in [`crate::sim::cache`]).
    pub fn index(&self) -> usize {
        match self {
            ModelId::DdpmCifar10 => 0,
            ModelId::LdmChurches => 1,
            ModelId::LdmBeds => 2,
            ModelId::StableDiffusion => 3,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelId::DdpmCifar10 => "DDPM",
            ModelId::LdmChurches => "LDM 1",
            ModelId::LdmBeds => "LDM 2",
            ModelId::StableDiffusion => "Stable Diffusion",
        }
    }

    pub fn dataset(&self) -> &'static str {
        match self {
            ModelId::DdpmCifar10 => "CIFAR-10",
            ModelId::LdmChurches => "LSUN-Churches",
            ModelId::LdmBeds => "LSUN-Beds",
            ModelId::StableDiffusion => "sd-v1-4",
        }
    }
}

/// A zoo entry: model metadata + UNet config + schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub id: ModelId,
    pub unet: UNetConfig,
    /// Denoising timesteps used at inference.
    pub timesteps: usize,
    /// Published parameter count (Table I).
    pub published_params: u64,
    /// Published IS reduction after W8A8 quantization (Table I), percent.
    pub published_is_drop_pct: f64,
    /// Pixel-space output resolution (for reporting).
    pub output_resolution: usize,
}

impl ModelSpec {
    /// Retrieve the spec for a model.
    pub fn get(id: ModelId) -> ModelSpec {
        match id {
            // DDPM on CIFAR-10: pixel space 32×32×3. Channel plan
            // calibrated to land the published 61.9 M parameters (a wider
            // variant of the 35.7 M Ho et al. baseline; width 125 ×
            // mults 1,2,3,3 reproduces Table I within 0.5%).
            ModelId::DdpmCifar10 => ModelSpec {
                id,
                unet: UNetConfig {
                    image_size: 32,
                    in_channels: 3,
                    out_channels: 3,
                    model_channels: 125,
                    channel_mult: vec![1, 2, 3, 3],
                    num_res_blocks: 2,
                    attention_resolutions: vec![2, 4],
                    num_heads: 4,
                    context_dim: None,
                    context_seq: 0,
                    transformer_layers: 1,
                    use_spatial_transformer: false,
                },
                timesteps: 1000,
                published_params: 61_900_000,
                published_is_drop_pct: 0.44,
                output_resolution: 32,
            },
            // LDM on LSUN-Churches (f=8 latents, 32×32×4): ch=192,
            // mults 1,2,3,4,4 — reproduces the published 294.96 M within
            // 0.5%.
            ModelId::LdmChurches => ModelSpec {
                id,
                unet: UNetConfig {
                    image_size: 32,
                    in_channels: 4,
                    out_channels: 4,
                    model_channels: 192,
                    channel_mult: vec![1, 2, 3, 4, 4],
                    num_res_blocks: 2,
                    attention_resolutions: vec![4, 8, 16],
                    num_heads: 8,
                    context_dim: None,
                    context_seq: 0,
                    transformer_layers: 1,
                    use_spatial_transformer: false,
                },
                timesteps: 200,
                published_params: 294_960_000,
                published_is_drop_pct: 0.43,
                output_resolution: 256,
            },
            // LDM on LSUN-Beds (f=4 latents, 64×64×3): ch=224,
            // mults 1,2,3,4 per the LDM reference config.
            ModelId::LdmBeds => ModelSpec {
                id,
                unet: UNetConfig {
                    image_size: 64,
                    in_channels: 3,
                    out_channels: 3,
                    model_channels: 224,
                    channel_mult: vec![1, 2, 3, 4],
                    num_res_blocks: 2,
                    attention_resolutions: vec![2, 4, 8],
                    num_heads: 8,
                    context_dim: None,
                    context_seq: 0,
                    transformer_layers: 1,
                    use_spatial_transformer: false,
                },
                timesteps: 200,
                published_params: 274_050_000,
                published_is_drop_pct: 5.26,
                output_resolution: 256,
            },
            // Stable Diffusion v1-4 UNet (f=8 latents, 64×64×4): ch=320,
            // mults 1,2,4,4, spatial transformers with CLIP (77×768)
            // cross-attention.
            ModelId::StableDiffusion => ModelSpec {
                id,
                unet: UNetConfig {
                    image_size: 64,
                    in_channels: 4,
                    out_channels: 4,
                    model_channels: 320,
                    channel_mult: vec![1, 2, 4, 4],
                    num_res_blocks: 2,
                    attention_resolutions: vec![1, 2, 4],
                    num_heads: 8,
                    context_dim: Some(768),
                    context_seq: 77,
                    transformer_layers: 1,
                    use_spatial_transformer: true,
                },
                timesteps: 50,
                published_params: 859_520_000,
                published_is_drop_pct: 6.66,
                output_resolution: 512,
            },
        }
    }

    /// Build the per-step layer trace.
    pub fn trace(&self) -> Vec<LayerInstance> {
        build_unet(&self.unet)
    }

    /// Stats of one denoising step.
    pub fn step_stats(&self) -> GraphStats {
        graph_stats(&self.trace())
    }

    /// Computed parameter count.
    pub fn computed_params(&self) -> u64 {
        self.step_stats().params
    }

    /// Relative deviation of computed vs published parameters.
    pub fn param_deviation(&self) -> f64 {
        let c = self.computed_params() as f64;
        let p = self.published_params as f64;
        (c - p).abs() / p
    }

    /// Total useful MACs of a full generation (all timesteps).
    pub fn total_macs(&self) -> u64 {
        self.step_stats().macs_per_step * self.timesteps as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_four_models_build() {
        for id in ModelId::ALL {
            let spec = ModelSpec::get(id);
            assert!(!spec.trace().is_empty(), "{:?} trace empty", id);
        }
    }

    #[test]
    fn param_counts_match_table1() {
        // Traces must land on the published Table I parameter counts.
        for id in ModelId::ALL {
            let spec = ModelSpec::get(id);
            let dev = spec.param_deviation();
            assert!(
                dev < 0.02,
                "{}: computed {}M vs published {}M ({:.1}% off)",
                spec.id.name(),
                spec.computed_params() / 1_000_000,
                spec.published_params / 1_000_000,
                dev * 100.0
            );
        }
    }

    #[test]
    fn sd_is_attention_heavy() {
        // §III.A: "SDMs … increasing the relative importance of
        // attention-heavy operations".
        let sd = ModelSpec::get(ModelId::StableDiffusion).step_stats();
        let ddpm = ModelSpec::get(ModelId::DdpmCifar10).step_stats();
        let sd_attn_frac = sd.attention_macs as f64 / sd.macs_per_step as f64;
        let ddpm_attn_frac = ddpm.attention_macs as f64 / ddpm.macs_per_step as f64;
        assert!(sd_attn_frac > ddpm_attn_frac);
    }

    #[test]
    fn timestep_counts() {
        assert_eq!(ModelSpec::get(ModelId::DdpmCifar10).timesteps, 1000);
        assert_eq!(ModelSpec::get(ModelId::StableDiffusion).timesteps, 50);
    }

    #[test]
    fn ddpm_total_macs_scale_with_timesteps() {
        let spec = ModelSpec::get(ModelId::DdpmCifar10);
        assert_eq!(spec.total_macs(), spec.step_stats().macs_per_step * 1000);
    }

    #[test]
    fn table1_metadata() {
        let sd = ModelSpec::get(ModelId::StableDiffusion);
        assert_eq!(sd.id.dataset(), "sd-v1-4");
        assert!((sd.published_is_drop_pct - 6.66).abs() < 1e-12);
    }
}
