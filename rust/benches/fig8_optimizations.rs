//! Figure 8 reproduction: "Energy improvements with dataflow and
//! scheduling optimizations."
//!
//! Regenerates the normalized per-model energy bars for Baseline,
//! S/W Optimized (sparse dataflow), Pipelined, DAC Sharing, and the
//! combination, and checks the paper's headline: combined optimizations
//! ≈ 3× lower energy on average.

#[path = "harness.rs"]
mod harness;

use difflight::arch::cost::OptFlags;
use difflight::sim::Simulator;
use difflight::util::stats;
use difflight::workload::{ModelId, ModelSpec};

fn main() {
    harness::section("Figure 8: normalized energy vs optimizations");
    let sim = Simulator::paper_optimal();
    let sweep = OptFlags::figure8_sweep();

    println!(
        "{:<18} {:>10} {:>10} {:>10} {:>11} {:>8}",
        "model", "Baseline", "S/W Opt", "Pipelined", "DAC Sharing", "All"
    );
    let mut combined = Vec::new();
    for id in ModelId::ALL {
        let spec = ModelSpec::get(id);
        let trace = spec.trace();
        let base = sim.step_cost(&trace, OptFlags::BASELINE).energy_j;
        let mut cells = Vec::new();
        for (_, opts) in sweep {
            let e = sim.step_cost(&trace, opts).energy_j;
            cells.push(e / base);
            if opts == OptFlags::ALL {
                combined.push(base / e);
            }
        }
        println!(
            "{:<18} {:>10.3} {:>10.3} {:>10.3} {:>11.3} {:>8.3}",
            spec.id.name(),
            cells[0],
            cells[1],
            cells[2],
            cells[3],
            cells[4]
        );
    }
    let avg = stats::mean(&combined);
    println!("\ncombined-optimization energy reduction: {avg:.2}x average");
    println!("paper: \"on average ... result in a 3x reduction in normalized energy\"");
    assert!(
        (2.0..4.5).contains(&avg),
        "combined reduction {avg:.2}x strays from the paper's ~3x"
    );

    harness::section("timing");
    let trace = ModelSpec::get(ModelId::StableDiffusion).trace();
    harness::bench("step_cost(SD, ALL)", 50, || {
        harness::black_box(sim.step_cost(&trace, OptFlags::ALL));
    });
}
