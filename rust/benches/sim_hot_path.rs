//! Perf-trajectory harness for the memoized cost pipeline (ISSUE 2).
//!
//! Times the two hot paths the `sim::cache` layer accelerates, each
//! before/after, and records the results in `BENCH_sim.json` at the
//! workspace root so the repo's perf trajectory is tracked in-tree:
//!
//! 1. **DSE sweep** — the full paper design-space exploration on the
//!    uncached reference path (`dse::explore_uncached`: trace rebuilt
//!    and every layer re-priced per candidate) vs the memoized path
//!    (`dse::explore`: interned traces + structural-signature cost
//!    memo). Asserts the two sweeps are **bit-identical** and that the
//!    memoized path is ≥5x faster.
//! 2. **Cluster drain** — a 10k-request fleet drain without step reuse
//!    vs with DeepCache `--reuse-interval 3`. Asserts samples are
//!    bit-identical and the simulated fleet throughput is ≥1.5x.
//! 3. **Fleet scale** — scheduler events/sec for the heap/index event
//!    core vs the retained O(events × devices) reference loop across
//!    devices ∈ {1, 4, 16, 64, 256}. Asserts the heap core beats the
//!    reference ≥5x at the 256-device point (≥1.2x at 64 devices in
//!    smoke mode, which sweeps {1, 16, 64}). The sharded event core
//!    (ISSUE 9) adds two more gates: the arena/4-ary data layout alone
//!    (1 shard) must beat the frozen pre-shard `LegacyStepScheduler`
//!    ≥1.2x at 256 devices, and a compute-dominated shard sweep
//!    (devices ∈ {256, 1024, 4096} × shards ∈ {1, 4, 8}) must reach
//!    ≥3x events/sec at the 4096-device 8-shard point vs 1 shard
//!    (asserted only on hosts with ≥8 workers; `--shards` forces the
//!    full sweep in smoke mode).
//! 4. **Fleet hetero** — a mixed big/small fleet (2 + 6 dies from the
//!    DSE family, per-profile priced) drained with cost-aware routing
//!    vs occupancy-only routing, plus an equal-device-count homogeneous
//!    paper fleet for reference. Asserts (a) a 2-profile fleet is
//!    bit-identical between the heap core and `ReferenceScheduler`
//!    (the `scripts/verify.sh` hetero parity gate) and (b) cost-aware
//!    routing lifts mixed-fleet throughput ≥1.2x — both deterministic
//!    simulated-time results, so they gate in smoke mode too.
//! 5. **SLO knee** — the paper fleet under open-loop Poisson load where
//!    every request carries a latency deadline (3 fused generations):
//!    sweeps the arrival rate to find the maximum rate sustaining ≥99%
//!    SLO attainment over *offered* load (sheds count as misses), then
//!    compares deadline-aware admission (`shed_late`) against
//!    shed-on-full at an overload rate. Asserts (a) a closed-loop
//!    client source is bit-identical between the heap core and the
//!    reference loop (the `scripts/verify.sh` closed-loop parity gate)
//!    and (b) deadline-aware shedding lifts goodput ≥1.2x at overload —
//!    simulated-time results, gated in smoke mode too.
//! 6. **Obs** — the streaming-observability tier (ISSUE 6). Asserts
//!    (a) the fixed-size `LogHistogram` reports p50/p99 within 1% of
//!    the exact-vector percentiles on both the slo_knee and the
//!    fleet-scale workloads, (b) the flight recorder costs ≤5%
//!    events/sec at 64 devices (min-of-N, trace on vs off), (c)
//!    metrics memory is O(buckets) not O(requests) — the serialized
//!    latency histogram grows ≤2x (and stays under the 8 bytes/sample
//!    a raw vector would need) while the request count grows 10x —
//!    and (d) a recorded trace round-trips through JSON lines and
//!    replays to metrics that match the live report bit-for-bit.
//! 7. **Resilience** — device churn under fault injection (ISSUE 7).
//!    Asserts (a) crashing 10% of a fleet mid-drain keeps goodput
//!    ≥ 0.8x the zero-fault baseline, (b) step-boundary
//!    checkpoint/migrate recovery loses zero requests (and the
//!    `--no-migration` ablation loses the victims, proving the
//!    mechanism is what saves them), (c) a seeded mixed fault plan
//!    (crash + outage + straggler + random recal churn) is
//!    bit-identical between the heap event core and the
//!    `ReferenceScheduler`, and records (d) an MTBF × fleet-size
//!    recalibration sweep as goodput-degradation curves.
//! 8. **Fleet DSE** — the fleet-composition search (ISSUE 10): a
//!    parallel, memoized, successive-halving sweep of `FleetSpace`
//!    candidates (`dse::explore_fleet`) vs the sequential unpruned
//!    oracle (`dse::explore_fleet_unpruned`). Asserts (a) the pruned
//!    winner's goodput-per-joule objective is within 2% of the
//!    unpruned optimum, (b) every final-rung survivor is bit-identical
//!    to its oracle evaluation (the memo changes nothing), (c) a
//!    re-sweep through the shared `FleetMemo` is pure hits with an
//!    identical ranking, and (d) in full mode the
//!    parallel+memoized+pruned sweep is ≥5x faster than the
//!    sequential unpruned baseline.
//!
//! `--smoke` runs a miniature of everything (tiny design space, 200
//! requests, 1-2 iterations) so `scripts/verify.sh` can keep the
//! harness from bit-rotting without paying full bench time. Ratio
//! assertions still run in smoke mode (the smoke fleet-scale gate is
//! the 64-device point at min-of-2 timing, so scheduler-scaling
//! regressions fail CI without load-spike flakiness). `--hetero` forces
//! the full-size hetero sweep (`scripts/bench.sh --hetero`); `--slo`
//! forces the full-size knee sweep (`scripts/bench.sh --slo`); `--obs`
//! forces the full-size observability section (`scripts/bench.sh
//! --obs`); `--faults` forces the full-size resilience section
//! (`scripts/bench.sh --faults`); `--brownout` forces the full-size
//! brownout/hedge/retry section (`scripts/bench.sh --brownout`);
//! `--shards` forces the full-size sharded-core layout gate and shard
//! sweep (`scripts/bench.sh --shards`); `--fleet-dse` forces the
//! full-size fleet-composition sweep with its ≥5x
//! parallel+memoized+pruned speedup gate (`scripts/bench.sh
//! --fleet-dse`).
//!
//! ## `BENCH_sim.json` schema
//!
//! ```json
//! {
//!   "bench": "sim_hot_path", "mode": "full|smoke", "threads": N,
//!   "dse": { "candidates": N, "iters": N,
//!            "uncached_s": mean, "cached_s": mean,
//!            "speedup": uncached/cached, "bit_identical": true,
//!            "cache": {"hits": N, "misses": N,
//!                       "layer_entries": N, "step_entries": N} },
//!   "cluster": { "requests": N, "steps": N, "devices": N,
//!     "no_reuse":  {"throughput_samples_per_s": x, "makespan_s": x,
//!                   "host_drain_s": x, "reuse_hits": 0},
//!     "reuse_k3":  {"throughput_samples_per_s": x, "makespan_s": x,
//!                   "host_drain_s": x, "reuse_hits": N,
//!                   "reuse_misses": N, "reuse_hit_rate": x},
//!     "throughput_ratio": t_k3 / t_k1 },
//!   "fleet_scale": { "steps": N, "reqs_per_device": N,
//!     "sweep": [ { "devices": N, "requests": N, "events": N,
//!                  "heap_events_per_s": x, "reference_events_per_s": x,
//!                  "speedup": x } ],
//!     "top_devices": N, "speedup_at_top": x,
//!     "layout": { "devices": N, "legacy_events_per_s": x,
//!                 "arena_events_per_s": x, "speedup": x },
//!     "shard_sweep": { "elems": N, "steps": N, "reqs_per_device": N,
//!       "sweep": [ { "devices": N, "shards": N, "events": N,
//!                    "events_per_s": x, "speedup_vs_1_shard": x } ],
//!       "top_devices": N, "top_shards": N, "speedup_at_top": x,
//!       "workers": N, "gate_enforced": bool } },
//!   "fleet_hetero": { "requests": N, "steps": N, "work_stealing": false,
//!     "big": {"arch": "[Y,N,K,H,L,M]", "count": N},
//!     "small": {"arch": "[Y,N,K,H,L,M]", "count": N},
//!     "mixed_mrs": N, "homogeneous_mrs": N,
//!     "cost_aware": {...}, "occupancy_only": {...},
//!     "homogeneous_equal_area": {...},
//!     "routing_gain": t_aware / t_blind, "parity_bit_identical": true },
//!   "slo_knee": { "devices": N, "capacity": N, "max_queue": N,
//!     "steps": N, "requests": N, "slo_ms": x, "fleet_rate_rps": x,
//!     "sweep": [ { "rate_rps": x, "offered": N, "completed": N,
//!                  "shed": N, "attainment": x,
//!                  "goodput_samples_per_s": x } ],
//!     "knee_rate_rps": x,
//!     "overload": { "rate_rps": x, "shed_late": {...},
//!                   "shed_on_full": {...}, "goodput_gain": x },
//!     "closed_loop_parity_bit_identical": true },
//!   "obs": { "quantiles": [ { "workload": "slo_knee|fleet_scale",
//!              "samples": N, "p50_exact_s": x, "p50_hist_s": x,
//!              "p50_rel_err": x, "p99_exact_s": x, "p99_hist_s": x,
//!              "p99_rel_err": x } ],
//!     "recorder": { "devices": N, "events": N,
//!       "plain_events_per_s": x, "traced_events_per_s": x,
//!       "overhead_frac": 1 - traced/plain },
//!     "memory": { "samples_1x": N, "hist_bytes_1x": N,
//!       "samples_10x": N, "hist_bytes_10x": N, "growth": x },
//!     "replay": { "events": N, "bit_identical": true } },
//!   "resilience": { "devices": N, "requests": N, "crashed": N,
//!     "baseline_goodput_samples_per_s": x,
//!     "degraded": { "goodput_ratio": x, "interrupted": N,
//!       "migrated": N, "retried": N, "lost": 0, "downtime_s": x },
//!     "ablation_lost": N, "parity_bit_identical": true,
//!     "sweep": [ { "devices": N, "mtbf_over_makespan": x,
//!                  "outages": N, "downtime_s": x,
//!                  "goodput_ratio": x } ] },
//!   "brownout": { "devices": N, "requests": N, "gen_s": x,
//!     "capacity_samples_per_s": x, "overload_rate_rps": x,
//!     "degraded_tiers": { "goodput_samples_per_s": x,
//!       "shed_only_goodput_samples_per_s": x, "goodput_gain": x,
//!       "degraded_admissions": N, "top_class_attainment": x },
//!     "hedge": { "requests": N, "p99_clean_s": x, "p99_straggler_s": x,
//!       "p99_hedged_s": x, "regression_recovered": x, "hedged": N,
//!       "cancelled": N, "duplicate_work_frac": x },
//!     "retry": { "requests": N, "ablation_lost": N, "retries": N,
//!       "lost": 0, "served": N },
//!     "parity_bit_identical": true },
//!   "fleet_dse": { "candidates": N, "budget_dies": N,
//!     "trace_requests": N, "steps": N, "rungs": N, "keep": x,
//!     "slo_target": x, "iters": N, "threads": N,
//!     "unpruned_s": mean, "pruned_cold_s": x, "pruned_memoized_s": mean,
//!     "speedup": unpruned/memoized, "cold_speedup": unpruned/cold,
//!     "gate_enforced": bool,
//!     "winner": "spec", "winner_objective": x,
//!     "oracle_winner": "spec", "oracle_objective": x, "winner_gap": x,
//!     "bit_identical": true,
//!     "memo": {"entries": N, "resweep_hits": N, "resweep_misses": 0},
//!     "step_cache": {"hits": N, "misses": N, "step_entries": N} }
//! }
//! ```

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use std::time::Instant;

use difflight::arch::ArchConfig;
use difflight::cluster::trace::{check_against_report, parse_jsonl, parse_jsonl_versioned, replay};
use difflight::cluster::{
    cache_for_width, default_recal_mttr_s, profile_step_costs, synthetic_workload,
    BrownoutConfig, Cluster, ClusterConfig, ClusterOutcome, FaultPlan, HedgePolicy,
    ReferenceScheduler, RequestSource, RetryPolicy, ShardPolicy, SimExecutor, StepScheduler,
    TraceEvent, TraceSink,
};
use difflight::coordinator::request::SamplerKind;
use difflight::devices::DeviceParams;
use difflight::runtime::manifest::NoiseSchedule;
use difflight::dse::{
    explore, explore_fleet, explore_fleet_unpruned, explore_uncached, explore_with, DesignSpace,
    FleetKnobs, FleetMemo, FleetSpace, FleetTrace,
};
use difflight::sim::CostCache;
use difflight::util::json::Json;
use difflight::util::stats;

fn smoke_space() -> DesignSpace {
    DesignSpace {
        y: vec![2, 4],
        n: vec![8, 12],
        k: vec![3],
        h: vec![4, 6],
        l: vec![6],
        m: vec![3],
        wavelengths: 36,
        max_total_mrs: usize::MAX,
    }
}

fn drain(devices: usize, requests: usize, steps: usize, reuse_interval: usize) -> (ClusterOutcome, f64) {
    let mut cluster = Cluster::simulated(
        ClusterConfig::with_devices(devices)
            .capacity(4)
            .max_queue(64)
            // Offline drain: defer overload instead of shedding it.
            .backlog(usize::MAX)
            .policy(ShardPolicy::LeastLoaded)
            .with_reuse(reuse_interval),
    )
    .expect("valid fleet");
    let workload = synthetic_workload(requests, 11, SamplerKind::Ddim { steps }, 0.0);
    let t0 = Instant::now();
    let out = cluster.serve(workload, &mut SimExecutor).expect("fleet drain");
    let host_s = t0.elapsed().as_secs_f64();
    assert_eq!(out.results.len(), requests, "offline drain must serve everything");
    (out, host_s)
}

fn cluster_json(out: &ClusterOutcome, host_s: f64) -> Json {
    let m = &out.metrics;
    Json::obj()
        .set("throughput_samples_per_s", m.throughput_samples_per_s())
        .set("makespan_s", m.makespan_s)
        .set("host_drain_s", host_s)
        .set("reuse_hits", m.reuse_hits())
        .set("reuse_misses", m.reuse_misses())
        .set("reuse_hit_rate", m.reuse_hit_rate())
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mode = if smoke { "smoke" } else { "full" };
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 8);
    let params = DeviceParams::paper();
    let space = if smoke { smoke_space() } else { DesignSpace::paper() };
    let candidates = space.candidates().len();
    let iters = if smoke { 1 } else { 3 };

    // ---- (a) DSE sweep: uncached reference vs memoized pipeline ----
    harness::section(&format!(
        "DSE sweep ({mode}): {candidates} candidates x 4 workloads, {threads} threads"
    ));
    // The timed closures keep their last result so the bit-identity
    // gate below doesn't pay for an extra (slow) uncached sweep.
    let mut ref_points = None;
    let mut memo_points = None;
    let uncached = harness::bench("explore uncached (reference)", iters, || {
        ref_points = Some(harness::black_box(explore_uncached(&space, &params, threads)));
    });
    let cached = harness::bench("explore memoized (sim::cache)", iters, || {
        memo_points = Some(harness::black_box(explore(&space, &params, threads)));
    });
    let speedup = uncached.mean_s / cached.mean_s;
    println!("DSE speedup (memoized vs uncached): {speedup:.1}x");

    // Correctness gate: the memoized sweep must be bit-identical.
    assert_eq!(
        ref_points.expect("bench ran"),
        memo_points.expect("bench ran"),
        "memoized sweep must be bit-identical"
    );
    if !smoke {
        assert!(
            speedup >= 5.0,
            "memoized DSE sweep must be >= 5x faster (got {speedup:.1}x)"
        );
    }

    // Cache shape after one sweep (fresh cache, so numbers are per-sweep).
    let cache = Arc::new(CostCache::new(params.clone()));
    harness::black_box(explore_with(&space, &params, threads, &cache));
    let cs = cache.stats();
    println!(
        "cache after one sweep: {} hits / {} misses ({:.1}% hit rate), {} layer + {} step entries",
        cs.hits,
        cs.misses,
        100.0 * cs.hit_rate(),
        cs.layer_entries,
        cs.step_entries,
    );

    // ---- (b) cluster drain: no reuse vs DeepCache K=3 ----
    let (requests, steps, devices) = if smoke { (200, 10, 4) } else { (10_000, 10, 4) };
    harness::section(&format!(
        "cluster drain ({mode}): {requests} requests x {steps} DDIM steps, {devices} devices"
    ));
    let (k1, k1_host) = drain(devices, requests, steps, 1);
    let (k3, k3_host) = drain(devices, requests, steps, 3);
    // Step reuse is a cost-model knob: generated samples must not move.
    // (Index by id — completion order may differ between reuse settings.)
    let mut k1_samples: Vec<Option<&Vec<f32>>> = vec![None; requests];
    for r in &k1.results {
        k1_samples[r.id.0 as usize] = Some(&r.sample);
    }
    for r in &k3.results {
        let a = k1_samples[r.id.0 as usize].expect("id served in both runs");
        assert_eq!(a, &r.sample, "reuse must not change samples");
    }
    let t1 = k1.metrics.throughput_samples_per_s();
    let t3 = k3.metrics.throughput_samples_per_s();
    let ratio = t3 / t1;
    println!(
        "no reuse:  {:.1} samples/s (sim), makespan {:.3}s, host {:.2}s",
        t1, k1.metrics.makespan_s, k1_host
    );
    println!(
        "reuse K=3: {:.1} samples/s (sim), makespan {:.3}s, host {:.2}s, hit rate {:.0}%",
        t3,
        k3.metrics.makespan_s,
        k3_host,
        100.0 * k3.metrics.reuse_hit_rate()
    );
    println!("simulated fleet throughput ratio: {ratio:.2}x");
    assert!(
        ratio >= 1.5,
        "reuse K=3 must lift simulated fleet throughput >= 1.5x (got {ratio:.2}x)"
    );

    // ---- (c) fleet scale: heap event core vs O(N) reference loop ----
    // Smoke sweeps up to the 64-device point (the CI gate, min-of-2 so
    // transient host load cannot flip it); full mode extends to 256
    // devices, where the >= 5x target is asserted.
    let (scale_devices, scale_iters): (Vec<usize>, usize) = if smoke {
        (vec![1, 16, 64], 2)
    } else {
        (vec![1, 4, 16, 64, 256], 3)
    };
    harness::section(&format!(
        "fleet scale ({mode}): devices in {scale_devices:?}, {} reqs/device x {} DDIM steps, \
         scheduler events/sec (host)",
        harness::FLEET_SCALE_REQS_PER_DEVICE,
        harness::FLEET_SCALE_STEPS,
    ));
    let mut scale_sweep = Vec::new();
    let mut top_speedup = 0.0f64;
    let top_devices = *scale_devices.last().expect("non-empty sweep");
    for &devices in &scale_devices {
        let (events, _, heap_eps) = harness::fleet_scale_time_core(devices, scale_iters, false);
        let (ref_events, _, ref_eps) = harness::fleet_scale_time_core(devices, scale_iters, true);
        assert_eq!(events, ref_events, "event counts must match (bit-identity)");
        let speedup = heap_eps / ref_eps;
        if devices == top_devices {
            top_speedup = speedup;
        }
        println!(
            "{devices:>4} devices: heap {heap_eps:>12.0} ev/s, reference {ref_eps:>12.0} ev/s \
             ({speedup:.1}x)"
        );
        scale_sweep.push(
            Json::obj()
                .set("devices", devices)
                .set("requests", devices * harness::FLEET_SCALE_REQS_PER_DEVICE)
                .set("events", events)
                .set("heap_events_per_s", heap_eps)
                .set("reference_events_per_s", ref_eps)
                .set("speedup", speedup),
        );
    }
    if smoke {
        assert!(
            top_speedup >= 1.2,
            "heap core must beat the reference loop >= 1.2x at {top_devices} devices \
             (got {top_speedup:.2}x)"
        );
    } else {
        assert!(
            top_speedup >= 5.0,
            "heap core must beat the reference loop >= 5x at {top_devices} devices \
             (got {top_speedup:.2}x)"
        );
    }

    // ---- (c') sharded event core: layout gate + shard sweep ----
    // Two separable claims from the sharding PR, gated separately:
    //
    // 1. **Layout gate** — the arena/4-ary data layout alone (1 shard,
    //    no parallel flush) must beat the frozen pre-shard core
    //    (`LegacyStepScheduler`) >= 1.2x events/sec at 256 devices on
    //    the scheduler-dominated fleet-scale workload.
    // 2. **Shard sweep** — events/sec at devices in {256, 1024, 4096}
    //    x shards in {1, 4, 8} on the compute-dominated shard-sweep
    //    workload, asserting >= 3x at the 4096-device 8-shard point vs
    //    1 shard (skipped, with a note, on hosts with < 8 workers —
    //    the speedup comes from real parallel step execution).
    //
    // `--shards` forces the full-size sweep even in smoke mode
    // (`scripts/bench.sh --shards`); smoke otherwise runs a miniature
    // (64 devices, shards {1, 4}, layout point at 64) without the
    // ratio asserts, which need the full-size points to be meaningful.
    let shards_full = !smoke || std::env::args().any(|a| a == "--shards");
    harness::section(&format!(
        "sharded event core ({}): layout gate + shards sweep",
        if shards_full { "full" } else { "smoke" }
    ));
    let layout_devices = if shards_full { 256 } else { 64 };
    let layout_iters = if shards_full { 3 } else { 2 };
    let (lg_events, _, legacy_eps) =
        harness::fleet_scale_time_legacy(layout_devices, layout_iters);
    let (ar_events, _, arena_eps) =
        harness::fleet_scale_time_core(layout_devices, layout_iters, false);
    assert_eq!(lg_events, ar_events, "the layout rewrite must not change the schedule");
    let layout_speedup = arena_eps / legacy_eps;
    println!(
        "layout gate at {layout_devices} devices: legacy {legacy_eps:.0} ev/s, \
         arena/4-ary {arena_eps:.0} ev/s ({layout_speedup:.2}x)"
    );
    if shards_full {
        assert!(
            layout_speedup >= 1.2,
            "the arena/4-ary layout alone (1 shard) must beat the pre-shard core \
             >= 1.2x at {layout_devices} devices (got {layout_speedup:.2}x)"
        );
    }
    let (shard_devices, shard_counts): (Vec<usize>, Vec<usize>) = if shards_full {
        (vec![256, 1024, 4096], vec![1, 4, 8])
    } else {
        (vec![64], vec![1, 4])
    };
    let top_shard_devices = *shard_devices.last().expect("non-empty sweep");
    let top_shard_count = *shard_counts.last().expect("non-empty sweep");
    let mut shard_sweep = Vec::new();
    let mut top_shard_speedup = 0.0f64;
    for &devices in &shard_devices {
        let mut base_eps = 0.0f64;
        let mut base_events = 0u64;
        for &shards in &shard_counts {
            let (events, _, eps) = harness::shard_sweep_time(devices, shards, 2);
            if shards == 1 {
                base_eps = eps;
                base_events = events;
            }
            assert_eq!(events, base_events, "shard count must not change the schedule");
            let speedup = eps / base_eps;
            if devices == top_shard_devices && shards == top_shard_count {
                top_shard_speedup = speedup;
            }
            println!(
                "{devices:>5} devices x {shards} shard(s): {eps:>12.0} ev/s ({speedup:.2}x vs 1 shard)"
            );
            shard_sweep.push(
                Json::obj()
                    .set("devices", devices)
                    .set("shards", shards)
                    .set("events", events)
                    .set("events_per_s", eps)
                    .set("speedup_vs_1_shard", speedup),
            );
        }
    }
    let workers = difflight::util::threadpool::ThreadPool::default_workers();
    let shard_gate_enforced = shards_full && workers >= 8;
    if shard_gate_enforced {
        assert!(
            top_shard_speedup >= 3.0,
            "{top_shard_count} shards must serve >= 3x the 1-shard events/sec at \
             {top_shard_devices} devices (got {top_shard_speedup:.2}x)"
        );
    } else if shards_full {
        println!(
            "{top_shard_count}-shard >= 3x gate skipped: only {workers} workers on this host \
             (needs >= 8 for the parallel flush to express the speedup)"
        );
    }

    // ---- (d) heterogeneous fleet: cost-aware vs occupancy-only ----
    // Mixed big/small DiffLight dies from the DSE family (shared
    // workload in benches/harness.rs). Smoke runs a miniature but still
    // asserts both gates — the parity check and the routing-gain ratio
    // are simulated-time results, deterministic under host load.
    // `--hetero` forces the full-size sweep even in smoke mode
    // (`scripts/bench.sh --hetero`).
    let hetero_full = !smoke || std::env::args().any(|a| a == "--hetero");
    let (h_requests, h_steps) = if hetero_full { (512, 12) } else { (160, 8) };
    harness::section(&format!(
        "fleet hetero ({}): {}x{:?} + {}x{:?}, {h_requests} requests x {h_steps} DDIM steps",
        if hetero_full { "full" } else { "smoke" },
        harness::HETERO_BIG_COUNT,
        harness::HETERO_BIG_ARCH,
        harness::HETERO_SMALL_COUNT,
        harness::HETERO_SMALL_ARCH,
    ));

    // Parity gate (runs in smoke too — scripts/verify.sh relies on it):
    // a 2-profile fleet must be bit-identical between the heap event
    // core and the ReferenceScheduler, metrics included.
    {
        let cfg = ClusterConfig::heterogeneous(harness::hetero_fleet())
            .max_queue(256)
            .backlog(usize::MAX);
        let costs = profile_step_costs(&cfg).expect("hetero fleet must price");
        let schedule = NoiseSchedule::linear(1000);
        let reqs = synthetic_workload(64, 23, SamplerKind::Ddim { steps: 8 }, 1e-5);
        let mut heap = StepScheduler::new(&cfg, &costs, schedule.clone(), 256);
        let mut reference = ReferenceScheduler::new(&cfg, &costs, schedule, 256);
        let a = heap.serve(reqs.clone(), &mut SimExecutor).expect("heap serve");
        let b = reference.serve(reqs, &mut SimExecutor).expect("reference serve");
        assert_eq!(a.metrics, b.metrics, "hetero parity: metrics diverged");
        assert_eq!(a.results.len(), b.results.len());
        for (ra, rb) in a.results.iter().zip(&b.results) {
            assert_eq!((ra.id, ra.device), (rb.id, rb.device), "hetero parity: placement");
            assert_eq!(ra.sample, rb.sample, "hetero parity: samples");
            assert!(ra.finish_s == rb.finish_s, "hetero parity: timings");
        }
        println!(
            "hetero parity gate: heap == reference over a 2-profile fleet \
             ({} events, bit-identical)",
            a.metrics.sched_events
        );
    }

    // Work stealing off in both arms: the comparison isolates routing.
    let mixed = || ClusterConfig::heterogeneous(harness::hetero_fleet()).stealing(false);
    let (aware, aware_host) = harness::hetero_drain(mixed().cost_aware(true), h_requests, h_steps);
    let (blind, blind_host) = harness::hetero_drain(mixed().cost_aware(false), h_requests, h_steps);
    // Equal-device-count homogeneous paper fleet as the area reference.
    let homog_cfg = ClusterConfig::with_devices(
        harness::HETERO_BIG_COUNT + harness::HETERO_SMALL_COUNT,
    )
    .stealing(false);
    let (homog, homog_host) = harness::hetero_drain(homog_cfg, h_requests, h_steps);
    // Routing never changes what gets generated.
    for (ra, rb) in aware.results.iter().zip(blind.results.iter()) {
        assert_eq!(ra.id, rb.id);
        assert_eq!(ra.sample, rb.sample, "routing must not change samples");
    }
    let t_aware = aware.metrics.throughput_samples_per_s();
    let t_blind = blind.metrics.throughput_samples_per_s();
    let routing_gain = t_aware / t_blind;
    let mixed_mrs = harness::HETERO_BIG_COUNT
        * ArchConfig::from_vector(harness::HETERO_BIG_ARCH, 36).total_mrs()
        + harness::HETERO_SMALL_COUNT
            * ArchConfig::from_vector(harness::HETERO_SMALL_ARCH, 36).total_mrs();
    let homog_mrs = (harness::HETERO_BIG_COUNT + harness::HETERO_SMALL_COUNT)
        * ArchConfig::paper_optimal().total_mrs();
    println!(
        "cost-aware:     {:.1} samples/s (sim), makespan {:.3}s, host {:.2}s",
        t_aware, aware.metrics.makespan_s, aware_host
    );
    println!(
        "occupancy-only: {:.1} samples/s (sim), makespan {:.3}s, host {:.2}s",
        t_blind, blind.metrics.makespan_s, blind_host
    );
    println!(
        "homogeneous:    {:.1} samples/s (sim, 8x paper die, {homog_mrs} MRs vs mixed {mixed_mrs}), host {:.2}s",
        homog.metrics.throughput_samples_per_s(),
        homog_host
    );
    println!("cost-aware routing gain over occupancy-only: {routing_gain:.2}x");
    assert!(
        routing_gain >= 1.2,
        "cost-aware routing must lift mixed-fleet throughput >= 1.2x \
         over occupancy-only (got {routing_gain:.2}x)"
    );

    // ---- (e) SLO knee: arrival-rate sweep + deadline-aware shedding ----
    // The closed-loop client tier and SLO-aware admission (ISSUE 5).
    // Smoke runs a miniature but still asserts both gates — parity and
    // the goodput gain are simulated-time results, deterministic under
    // host load. `--slo` forces the full-size sweep (scripts/bench.sh
    // --slo).
    let slo_full = !smoke || std::env::args().any(|a| a == "--slo");
    let knee_requests = if slo_full { 480 } else { 120 };
    let (fleet_rate, slo_s) = harness::slo_workload_params();
    harness::section(&format!(
        "slo knee ({}): {} paper dies (cap {}, q {}), {knee_requests} Poisson requests x {} \
         DDIM steps, slo {:.2} ms, fleet rate {:.0} rps",
        if slo_full { "full" } else { "smoke" },
        harness::SLO_DEVICES,
        harness::SLO_CAPACITY,
        harness::SLO_MAX_QUEUE,
        harness::SLO_STEPS,
        slo_s * 1e3,
        fleet_rate,
    ));

    // Closed-loop parity gate (runs in smoke too — scripts/verify.sh
    // relies on it): a closed-loop client source, whose arrivals depend
    // on completion feedback, must be bit-identical between the heap
    // event core and the ReferenceScheduler, metrics included.
    {
        let cfg = ClusterConfig::with_devices(4).capacity(2).max_queue(4).shed_late(true);
        let costs = profile_step_costs(&cfg).expect("paper fleet must price");
        let schedule = NoiseSchedule::linear(1000);
        let src = RequestSource::closed_loop(
            6,
            slo_s * 0.1,
            96,
            41,
            SamplerKind::Ddim { steps: 8 },
        )
        .with_slos(vec![slo_s, 4.0 * slo_s]);
        let mut heap = StepScheduler::new(&cfg, &costs, schedule.clone(), 256);
        let mut reference = ReferenceScheduler::new(&cfg, &costs, schedule, 256);
        let a = heap.serve_source(src.clone(), &mut SimExecutor).expect("heap serve");
        let b = reference.serve_source(src, &mut SimExecutor).expect("reference serve");
        assert_eq!(a.rejected, b.rejected, "closed-loop parity: shed set diverged");
        assert_eq!(a.metrics, b.metrics, "closed-loop parity: metrics diverged");
        assert_eq!(a.results.len(), b.results.len());
        for (ra, rb) in a.results.iter().zip(&b.results) {
            assert_eq!((ra.id, ra.device), (rb.id, rb.device), "closed-loop parity: placement");
            assert_eq!(ra.sample, rb.sample, "closed-loop parity: samples");
            assert!(
                ra.finish_s == rb.finish_s && ra.arrival_s == rb.arrival_s,
                "closed-loop parity: timings"
            );
        }
        println!(
            "closed-loop parity gate: heap == reference over 6 clients x 96 submissions \
             ({} events, bit-identical)",
            a.metrics.sched_events
        );
    }

    // Arrival-rate sweep under deadline-aware admission: attainment over
    // offered load (sheds count as misses) traces the knee.
    let rate_mults: &[f64] = if slo_full {
        &[0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 3.0]
    } else {
        &[0.25, 1.0, 3.0]
    };
    let mut knee_sweep = Vec::new();
    let mut knee_rate = 0.0f64;
    for &mult in rate_mults {
        let rate = mult * fleet_rate;
        let out = harness::slo_drain(rate, knee_requests, slo_s, true);
        let m = &out.metrics;
        let attainment = m.slo_attainment();
        let offered = out.results.len() + out.rejected.len();
        assert_eq!(offered, knee_requests, "every offered request completes or sheds");
        if attainment >= 0.99 && rate > knee_rate {
            knee_rate = rate;
        }
        println!(
            "rate {:>7.0} rps ({mult:.1}x): attainment {:>5.1}%, goodput {:>7.1} samples/s, \
             {} shed",
            rate,
            100.0 * attainment,
            m.goodput_samples_per_s(),
            out.shed(),
        );
        knee_sweep.push(
            Json::obj()
                .set("rate_rps", rate)
                .set("offered", offered)
                .set("completed", out.results.len())
                .set("shed", out.shed())
                .set("attainment", attainment)
                .set("goodput_samples_per_s", m.goodput_samples_per_s()),
        );
    }
    assert!(
        knee_rate > 0.0,
        "the paper fleet must sustain >= 99% SLO attainment at some swept rate"
    );
    println!("max sustainable rate at 99% attainment: {knee_rate:.0} rps");

    // Overload gate: deadline-aware shedding vs shed-on-full admission.
    // Doomed requests camping on queues drag every later request past
    // the deadline; shedding them at admission keeps the fleet serving
    // work that can still meet its SLO.
    let overload_rate = 3.0 * fleet_rate;
    let kept = harness::slo_drain(overload_rate, knee_requests, slo_s, true);
    let full = harness::slo_drain(overload_rate, knee_requests, slo_s, false);
    let goodput_gain =
        kept.metrics.goodput_samples_per_s() / full.metrics.goodput_samples_per_s();
    println!(
        "overload {:.0} rps: shed-late goodput {:.1} ({} shed), shed-on-full goodput {:.1} \
         ({} shed) -> {goodput_gain:.2}x",
        overload_rate,
        kept.metrics.goodput_samples_per_s(),
        kept.shed(),
        full.metrics.goodput_samples_per_s(),
        full.shed(),
    );
    assert!(
        goodput_gain >= 1.2,
        "deadline-aware shedding must lift goodput >= 1.2x over shed-on-full admission \
         at overload (got {goodput_gain:.2}x)"
    );

    // ---- (f) obs: streaming histograms + flight recorder ----
    // The observability tier (ISSUE 6). Everything except the recorder
    // overhead ratio is a deterministic simulated-time result, so the
    // accuracy/memory/replay gates run in smoke mode too; the overhead
    // gate is min-of-N host timing at 64 devices (matching the
    // fleet-scale CI gate's flake resistance). `--obs` forces the
    // full-size runs (`scripts/bench.sh --obs`).
    let obs_full = !smoke || std::env::args().any(|a| a == "--obs");
    harness::section(&format!("obs ({})", if obs_full { "full" } else { "smoke" }));

    // Gate (a): histogram p50/p99 within 1% of the exact-vector
    // percentiles — the live metrics keep only O(buckets) state, so
    // the exact vector is rebuilt here from the per-request results.
    let obs_scale_devices = if obs_full { 64 } else { 16 };
    let fleet_scale_out = harness::fleet_scale_outcome(obs_scale_devices);
    let mut obs_quantiles = Vec::new();
    for (workload, out) in [("slo_knee", &kept), ("fleet_scale", &fleet_scale_out)] {
        let exact: Vec<f64> = out.results.iter().map(|r| r.latency_s()).collect();
        assert_eq!(exact.len() as u64, out.metrics.latency.count());
        let mut entry = Json::obj().set("workload", workload).set("samples", exact.len());
        for (p, label) in [(50.0, "p50"), (99.0, "p99")] {
            let exact_v = stats::percentile(&exact, p);
            let hist_v = out.metrics.latency.quantile(p);
            let rel_err = if exact_v != 0.0 {
                ((hist_v - exact_v) / exact_v).abs()
            } else {
                hist_v.abs()
            };
            println!(
                "{workload} {label}: exact {exact_v:.6e}s, hist {hist_v:.6e}s \
                 ({:.3}% rel err)",
                100.0 * rel_err
            );
            assert!(
                rel_err <= 0.01,
                "{workload} {label}: histogram quantile must be within 1% of the \
                 exact-vector percentile (got {:.3}%)",
                100.0 * rel_err
            );
            entry = entry
                .set(&format!("{label}_exact_s"), exact_v)
                .set(&format!("{label}_hist_s"), hist_v)
                .set(&format!("{label}_rel_err"), rel_err);
        }
        obs_quantiles.push(entry);
    }

    // Gate (b): flight-recorder overhead <= 5% events/sec at 64
    // devices. The sink buffers Copy structs during the serve loop and
    // formats nothing, so trace-on must stay within 5% of trace-off.
    let obs_iters = if obs_full { 3 } else { 2 };
    let (rec_events, _, plain_eps) =
        harness::fleet_scale_time_core_traced(64, obs_iters, false, false);
    let (traced_events, _, traced_eps) =
        harness::fleet_scale_time_core_traced(64, obs_iters, false, true);
    assert_eq!(rec_events, traced_events, "tracing must not change the schedule");
    let overhead = 1.0 - traced_eps / plain_eps;
    println!(
        "recorder overhead at 64 devices: plain {plain_eps:.0} ev/s, traced \
         {traced_eps:.0} ev/s ({:.1}%)",
        100.0 * overhead
    );
    assert!(
        overhead <= 0.05,
        "flight recorder must cost <= 5% events/sec at 64 devices (got {:.1}%)",
        100.0 * overhead
    );

    // Gate (c): metrics memory is O(buckets), not O(requests). A
    // stationary workload 10x longer must not grow the serialized
    // histogram materially (new samples land in occupied buckets), and
    // the histogram must undercut the 8 bytes/sample a raw f64 vector
    // would need.
    let mem_requests = if obs_full { 400 } else { 200 };
    let mem_rate = 0.6 * fleet_rate;
    let mem_1x = harness::slo_drain(mem_rate, mem_requests, slo_s, false);
    let mem_10x = harness::slo_drain(mem_rate, 10 * mem_requests, slo_s, false);
    let bytes_1x = mem_1x.metrics.latency.to_json().to_string_compact().len();
    let bytes_10x = mem_10x.metrics.latency.to_json().to_string_compact().len();
    let growth = bytes_10x as f64 / bytes_1x as f64;
    println!(
        "metrics memory: {} samples -> {bytes_1x} hist bytes, {} samples -> \
         {bytes_10x} hist bytes ({growth:.2}x for 10x the requests)",
        mem_1x.results.len(),
        mem_10x.results.len(),
    );
    assert!(
        growth <= 2.0,
        "histogram JSON must stay O(buckets): 10x the requests grew it {growth:.2}x"
    );
    assert!(
        bytes_10x < mem_10x.results.len() * 8,
        "histogram ({bytes_10x} bytes) must undercut a raw sample vector \
         ({} samples x 8 bytes)",
        mem_10x.results.len()
    );

    // Gate (d): trace replay round-trips bit-identically. A contended
    // run (tight queues, deadline shedding, stealing) is traced,
    // formatted as JSON lines, parsed back, and replayed; the
    // reconstructed histograms and counters must match the live
    // report's exported values exactly.
    let replay_events = {
        let cfg = ClusterConfig::with_devices(8)
            .capacity(2)
            .max_queue(4)
            .policy(ShardPolicy::LeastLoaded)
            .shed_late(true);
        let costs = profile_step_costs(&cfg).expect("paper fleet must price");
        let src = RequestSource::poisson(
            if obs_full { 256 } else { 96 },
            31,
            SamplerKind::Ddim { steps: harness::SLO_STEPS },
            2.0 * fleet_rate,
        )
        .with_slos(vec![slo_s, 4.0 * slo_s]);
        let mut s = StepScheduler::new(&cfg, &costs, NoiseSchedule::linear(1000), 256);
        s.set_trace(TraceSink::new());
        let live = s.serve_source(src, &mut SimExecutor).expect("traced serve");
        let sink = s.take_trace().expect("sink survives the serve");
        let parsed = parse_jsonl(&sink.to_jsonl()).expect("recorder output must parse");
        assert_eq!(parsed, *sink.events(), "JSON-lines round trip must be lossless");
        let rep = replay(&parsed);
        let bad = check_against_report(&rep, &live.metrics.to_json());
        assert!(bad.is_empty(), "trace replay diverged from the live report on {bad:?}");
        println!(
            "trace replay: {} events round-tripped, replayed metrics bit-identical \
             ({} completions, {} shed)",
            parsed.len(),
            live.results.len(),
            live.rejected.len(),
        );
        parsed.len()
    };

    // ---- (g) resilience: device churn and degradation curves ----
    // Fault injection + step-boundary migration (ISSUE 7). Every gate
    // here is a deterministic simulated-time result, so all of them run
    // in smoke mode too; `--faults` forces the full-size fleet and the
    // full MTBF sweep (`scripts/bench.sh --faults`).
    let faults_full = !smoke || std::env::args().any(|a| a == "--faults");
    let res_devices = if faults_full { 20 } else { 10 };
    let res_requests = res_devices * harness::FLEET_SCALE_REQS_PER_DEVICE;
    harness::section(&format!(
        "resilience ({}): {res_devices} devices x {} reqs/device, crash 10% mid-drain",
        if faults_full { "full" } else { "smoke" },
        harness::FLEET_SCALE_REQS_PER_DEVICE,
    ));

    // Gates (a)+(b): crash 10% of the fleet a quarter of the way into
    // the zero-fault makespan. Migration must rescue every in-flight
    // and queued victim (offline semantics: nothing else can drop
    // work), and goodput must hold >= 0.8x the zero-fault baseline.
    let res_baseline = harness::churn_drain(res_devices, FaultPlan::default(), true);
    assert_eq!(res_baseline.results.len(), res_requests, "zero-fault drain serves everything");
    let res_m0 = res_baseline.metrics.makespan_s;
    let crash_n = res_devices / 10;
    let mut crash_plan = FaultPlan::new();
    for d in 0..crash_n {
        crash_plan = crash_plan.crash_at(0.25 * res_m0, d);
    }
    let degraded = harness::churn_drain(res_devices, crash_plan.clone(), true);
    let baseline_goodput = res_baseline.metrics.goodput_samples_per_s();
    let goodput_ratio = degraded.metrics.goodput_samples_per_s() / baseline_goodput;
    println!(
        "crash {crash_n}/{res_devices} at 0.25x makespan: {} interrupted, {} migrated, \
         {} requeued, {} lost, downtime {:.1} ms, goodput {goodput_ratio:.3}x baseline",
        degraded.metrics.interrupted(),
        degraded.metrics.migrated(),
        degraded.metrics.retried(),
        degraded.metrics.lost(),
        1e3 * degraded.metrics.downtime_s(),
    );
    assert_eq!(
        degraded.results.len(),
        res_requests,
        "step-boundary migration must rescue every fault victim"
    );
    assert_eq!(degraded.metrics.lost(), 0, "no request may be lost with migration enabled");
    assert!(
        degraded.metrics.interrupted() > 0,
        "a mid-drain crash must interrupt in-flight work (else the gate tests nothing)"
    );
    assert!(
        goodput_ratio >= 0.8,
        "10% device loss must keep goodput >= 0.8x the zero-fault baseline \
         (got {goodput_ratio:.3}x)"
    );
    // Ablation: with migration off the same crashes lose the victims,
    // so the rescue above is attributable to the mechanism.
    let ablation = harness::churn_drain(res_devices, crash_plan, false);
    let ablation_lost = ablation.metrics.lost();
    println!(
        "--no-migration ablation: {} served, {ablation_lost} lost",
        ablation.results.len()
    );
    assert!(ablation_lost > 0, "the ablation must lose victims, else migration is untested");

    // Gate (c): heap core == reference loop under a seeded mixed plan
    // (crash + recal outage + straggler + random recal churn), metrics,
    // placements, samples and timings all bit-identical.
    {
        let base_cfg = ClusterConfig::with_devices(8)
            .capacity(2)
            .max_queue(8)
            .backlog(usize::MAX)
            .policy(ShardPolicy::LeastLoaded);
        let costs = profile_step_costs(&base_cfg).expect("paper fleet must price");
        let schedule = NoiseSchedule::linear(1000);
        let reqs = synthetic_workload(96, 37, SamplerKind::Ddim { steps: 8 }, 1e-5);
        // Probe the fault-free makespan so the plan lands mid-drain
        // regardless of the priced step time.
        let mut probe = StepScheduler::new(&base_cfg, &costs, schedule.clone(), 256);
        let mp = probe
            .serve(reqs.clone(), &mut SimExecutor)
            .expect("probe serve")
            .metrics
            .makespan_s;
        let mut plan = FaultPlan::new()
            .crash_at(0.2 * mp, 1)
            .outage_at(0.35 * mp, 3, 0.15 * mp)
            .slow_at(0.1 * mp, 5, 2.5);
        plan.extend(&FaultPlan::recal(8, mp, 0.05 * mp, mp, 9));
        let cfg = base_cfg.faults(plan);
        let mut heap = StepScheduler::new(&cfg, &costs, schedule.clone(), 256);
        let mut reference = ReferenceScheduler::new(&cfg, &costs, schedule, 256);
        let a = heap.serve(reqs.clone(), &mut SimExecutor).expect("heap serve");
        let b = reference.serve(reqs, &mut SimExecutor).expect("reference serve");
        assert_eq!(a.metrics, b.metrics, "churn parity: metrics diverged");
        assert_eq!(a.rejected, b.rejected, "churn parity: rejection set diverged");
        assert_eq!(a.results.len(), b.results.len());
        for (ra, rb) in a.results.iter().zip(&b.results) {
            assert_eq!((ra.id, ra.device), (rb.id, rb.device), "churn parity: placement");
            assert_eq!(ra.sample, rb.sample, "churn parity: samples");
            assert!(ra.finish_s == rb.finish_s, "churn parity: timings");
        }
        println!(
            "churn parity gate: heap == reference under {} fault events \
             ({} sched events, bit-identical)",
            cfg.faults.len(),
            a.metrics.sched_events
        );
    }

    // Curve (d): MTBF x fleet-size recalibration sweep. Each point
    // drains the fleet-scale workload under seeded recal churn with the
    // default full-array-relock MTTR and reports goodput relative to
    // that fleet's zero-fault baseline.
    let (sweep_sizes, mtbf_mults): (&[usize], &[f64]) = if faults_full {
        (&[8, 16, 32], &[0.5, 1.0, 2.0, 4.0])
    } else {
        (&[4, 8], &[1.0, 4.0])
    };
    let mut res_sweep = Vec::new();
    for &d in sweep_sizes {
        let base = harness::churn_drain(d, FaultPlan::default(), true);
        let g0 = base.metrics.goodput_samples_per_s();
        for &mult in mtbf_mults {
            let plan = FaultPlan::recal(
                d,
                mult * base.metrics.makespan_s,
                default_recal_mttr_s(),
                base.metrics.makespan_s,
                9,
            );
            let outages = plan.len();
            let out = harness::churn_drain(d, plan, true);
            assert_eq!(out.metrics.lost(), 0, "recal churn with migration must lose nothing");
            let ratio = out.metrics.goodput_samples_per_s() / g0;
            println!(
                "{d:>3} devices, mtbf {mult:.1}x makespan: {outages:>3} outages, \
                 downtime {:>7.1} ms, goodput {ratio:.3}x",
                1e3 * out.metrics.downtime_s(),
            );
            res_sweep.push(
                Json::obj()
                    .set("devices", d)
                    .set("mtbf_over_makespan", mult)
                    .set("outages", outages)
                    .set("downtime_s", out.metrics.downtime_s())
                    .set("goodput_ratio", ratio),
            );
        }
    }

    // ---- (h) brownout, hedged requests, and retry budgets ----
    // The client-side resilience tier (ISSUE 8). Three gates plus a
    // parity check, all deterministic simulated-time results so they
    // run in smoke mode too; `--brownout` forces the full-size runs
    // (`scripts/bench.sh --brownout`).
    let brownout_full = !smoke || std::env::args().any(|a| a == "--brownout");
    harness::section(&format!(
        "brownout / hedge / retry ({})",
        if brownout_full { "full" } else { "smoke" },
    ));
    let bo_steps = 8usize;

    // Gate (1): brownout beats shed-only overload control. Class 0 is
    // the protected top tier (1 request in 4, generous SLO); classes
    // 1-3 are degradable bulk traffic on a tight SLO. At 2x the
    // fleet's measured capacity the controller must find a degraded
    // operating point that serves >= 1.2x the shed-only goodput while
    // the undegraded top class stays >= 99% attained.
    //
    // Scale the overload and the SLO ladder from measured times, not
    // hard-coded seconds: a solo request prices one generation, a
    // saturated drain prices fleet capacity.
    let bo_devices = if brownout_full { 8 } else { 4 };
    let bo_requests = if brownout_full { 800 } else { 400 };
    let solo_cfg = ClusterConfig::with_devices(1).capacity(2).max_queue(4);
    let solo_costs = profile_step_costs(&solo_cfg).expect("solo fleet must price");
    let mut solo = StepScheduler::new(&solo_cfg, &solo_costs, NoiseSchedule::linear(1000), 256);
    let gen_s = solo
        .serve(synthetic_workload(1, 3, SamplerKind::Ddim { steps: bo_steps }, 0.0), &mut SimExecutor)
        .expect("solo probe")
        .results[0]
        .latency_s();
    let bo_cfg = ClusterConfig::with_devices(bo_devices)
        .capacity(2)
        .max_queue(64)
        .backlog(usize::MAX)
        .policy(ShardPolicy::LeastLoaded)
        .shed_late(true);
    let bo_costs = profile_step_costs(&bo_cfg).expect("brownout fleet must price");
    let bo_cap_rate = {
        let cfg = bo_cfg.clone().shed_late(false);
        let mut s = StepScheduler::new(&cfg, &bo_costs, NoiseSchedule::linear(1000), 256);
        s.serve(
            synthetic_workload(bo_devices * 24, 7, SamplerKind::Ddim { steps: bo_steps }, 0.0),
            &mut SimExecutor,
        )
        .expect("capacity probe")
        .metrics
        .throughput_samples_per_s()
    };
    let bo_rate = 2.0 * bo_cap_rate;
    let bo_slos = vec![30.0 * gen_s, 6.0 * gen_s, 6.0 * gen_s, 6.0 * gen_s];
    let bo_serve = |brownout: Option<BrownoutConfig>| {
        let mut cfg = bo_cfg.clone();
        if let Some(b) = brownout {
            cfg = cfg.brownout(b);
        }
        let mut s = StepScheduler::new(&cfg, &bo_costs, NoiseSchedule::linear(1000), 256);
        let src =
            RequestSource::poisson(bo_requests, 23, SamplerKind::Ddim { steps: bo_steps }, bo_rate)
                .with_slos(bo_slos.clone());
        s.serve_source(src, &mut SimExecutor).expect("overload serve")
    };
    let bo_shed_only = bo_serve(None);
    let bo_browned = bo_serve(Some(BrownoutConfig::new(0.95, 32, 2, 0.5)));
    let bo_g_shed = bo_shed_only.metrics.goodput_samples_per_s();
    let bo_g_deg = bo_browned.metrics.goodput_samples_per_s();
    let bo_gain = bo_g_deg / bo_g_shed;
    let bo_top = bo_browned.metrics.classes[0].attainment();
    println!(
        "brownout gate: 2.0x overload ({bo_rate:.0} rps), shed-only {bo_g_shed:.1} -> \
         degraded-tier {bo_g_deg:.1} samples/s ({bo_gain:.2}x), {} degraded admissions, \
         top-class attainment {:.4}",
        bo_browned.metrics.degraded(),
        bo_top,
    );
    assert!(
        bo_browned.metrics.degraded() > 0,
        "the overload must actually engage the brownout controller"
    );
    assert!(
        bo_gain >= 1.2,
        "degraded-tier serving must beat shed-only goodput by >= 1.2x (got {bo_gain:.3}x)"
    );
    assert!(
        bo_top >= 0.99,
        "brownout must hold >= 99% attainment on the undegraded top class (got {bo_top:.4})"
    );

    // Gate (2): hedged requests rescue straggler residents. Two dies
    // turn 40x slow mid-drain; work stealing already drains their
    // queues, so the tail is exactly the work *running* there. Hedging
    // at a fixed threshold (the clean run's p99) must claw back >= 90%
    // of the straggler-induced p99 regression for <= 10% duplicated
    // denoise steps.
    let hg_devices = 8;
    let hg_requests = if brownout_full { 480 } else { 320 };
    let hg_cfg = ClusterConfig::with_devices(hg_devices)
        .capacity(4)
        .max_queue(64)
        .backlog(usize::MAX)
        .policy(ShardPolicy::LeastLoaded);
    let hg_costs = profile_step_costs(&hg_cfg).expect("hedge fleet must price");
    let hg_reqs = synthetic_workload(hg_requests, 29, SamplerKind::Ddim { steps: bo_steps }, 0.0);
    let hg_serve = |plan: FaultPlan, hedge: Option<HedgePolicy>, trace: bool| {
        let mut cfg = hg_cfg.clone().faults(plan);
        if let Some(h) = hedge {
            cfg = cfg.hedge(h);
        }
        let mut s = StepScheduler::new(&cfg, &hg_costs, NoiseSchedule::linear(1000), 256);
        if trace {
            s.set_trace(TraceSink::new());
        }
        let out = s.serve(hg_reqs.clone(), &mut SimExecutor).expect("hedge serve");
        let sink = if trace { s.take_trace() } else { None };
        (out, sink)
    };
    let (hg_clean, _) = hg_serve(FaultPlan::new(), None, false);
    let hg_p99_clean = hg_clean.metrics.latency_p99_s();
    let hg_mp = hg_clean.metrics.makespan_s;
    let hg_plan = || {
        FaultPlan::new().slow_at(0.25 * hg_mp, 0, 40.0).slow_at(0.25 * hg_mp, 1, 40.0)
    };
    let (hg_unhedged, _) = hg_serve(hg_plan(), None, false);
    let hg_p99_slow = hg_unhedged.metrics.latency_p99_s();
    let (hg_hedged, hg_trace) = hg_serve(hg_plan(), Some(HedgePolicy::fixed(hg_p99_clean)), true);
    let hg_p99_hedged = hg_hedged.metrics.latency_p99_s();
    assert_eq!(hg_unhedged.results.len(), hg_requests, "stragglers alone must not lose work");
    assert_eq!(
        hg_hedged.results.len(),
        hg_requests,
        "hedging must neither lose nor double-serve a request"
    );
    assert!(
        hg_p99_slow > 1.25 * hg_p99_clean,
        "the seeded stragglers must damage the unhedged p99, else the gate tests nothing"
    );
    assert!(hg_hedged.metrics.hedged() > 0, "the stragglers must trip the hedge threshold");
    let hg_recovery = (hg_p99_slow - hg_p99_hedged) / (hg_p99_slow - hg_p99_clean);
    // Every step a cancelled loser executed is a step the fleet spent
    // twice; sum the duplicate cost straight off the flight recorder.
    let hg_dup_steps: u64 = hg_trace
        .as_ref()
        .expect("trace attached")
        .events()
        .iter()
        .map(|ev| match *ev {
            TraceEvent::Cancel { steps, .. } => steps,
            _ => 0,
        })
        .sum();
    let hg_total_steps: u64 = hg_hedged.metrics.devices.iter().map(|d| d.steps_executed).sum();
    let hg_dup_frac = hg_dup_steps as f64 / hg_total_steps as f64;
    println!(
        "hedge gate: p99 clean {:.2} ms, straggler {:.2} ms, hedged {:.2} ms \
         (recovered {:.0}% of the regression); {} hedged, {} cancelled, \
         duplicate work {:.2}%",
        1e3 * hg_p99_clean,
        1e3 * hg_p99_slow,
        1e3 * hg_p99_hedged,
        100.0 * hg_recovery,
        hg_hedged.metrics.hedged(),
        hg_hedged.metrics.cancelled(),
        100.0 * hg_dup_frac,
    );
    assert!(
        hg_recovery >= 0.9,
        "hedging must recover >= 0.9x of the straggler p99 regression (got {hg_recovery:.3})"
    );
    assert!(
        hg_dup_frac <= 0.10,
        "hedge duplicates must cost <= 10% extra denoise steps (got {hg_dup_frac:.3})"
    );

    // Gate (3): retry budgets turn fault losses into served requests.
    // Crash two dies mid-drain with migration off — the in-fleet rescue
    // path is gone, so without retries the victims are lost; with a
    // retry budget every loss re-enters the arrival stream after
    // jittered exponential backoff and completes. Zero lost.
    let rt_devices = 10;
    let rt_requests = rt_devices * 24;
    let rt_cfg = ClusterConfig::with_devices(rt_devices)
        .capacity(2)
        .max_queue(8)
        .backlog(usize::MAX)
        .policy(ShardPolicy::LeastLoaded)
        .migration(false);
    let rt_costs = profile_step_costs(&rt_cfg).expect("retry fleet must price");
    let rt_reqs = synthetic_workload(rt_requests, 31, SamplerKind::Ddim { steps: bo_steps }, 0.0);
    let rt_mp = {
        let mut s = StepScheduler::new(&rt_cfg, &rt_costs, NoiseSchedule::linear(1000), 256);
        s.serve(rt_reqs.clone(), &mut SimExecutor).expect("retry probe").metrics.makespan_s
    };
    let rt_plan = FaultPlan::new().crash_at(0.25 * rt_mp, 0).crash_at(0.25 * rt_mp, 1);
    let rt_serve = |retry: Option<RetryPolicy>| {
        let cfg = rt_cfg.clone().faults(rt_plan.clone());
        let mut s = StepScheduler::new(&cfg, &rt_costs, NoiseSchedule::linear(1000), 256);
        let mut src = RequestSource::replay(rt_reqs.clone());
        if let Some(p) = retry {
            src = src.with_retry(p, 3);
        }
        s.serve_source(src, &mut SimExecutor).expect("retry serve")
    };
    let rt_without = rt_serve(None);
    assert!(
        rt_without.metrics.lost() > 0,
        "the no-retry ablation must lose the crash victims, else retries are untested"
    );
    let rt_with = rt_serve(Some(RetryPolicy::new(5, 0.05 * rt_mp, 1.0)));
    println!(
        "retry gate: {} lost without retries; with them {} retries, {} lost, {}/{} served",
        rt_without.metrics.lost(),
        rt_with.metrics.retries(),
        rt_with.metrics.lost(),
        rt_with.results.len(),
        rt_requests,
    );
    assert!(rt_with.metrics.retries() > 0, "the crash must actually trigger retries");
    assert_eq!(rt_with.metrics.lost(), 0, "retry budgets must add zero lost requests");
    assert_eq!(rt_with.results.len(), rt_requests, "every victim must resubmit and finish");
    assert!(rt_with.rejected.is_empty(), "nothing may be shed on an unconstrained backlog");

    // Parity: all three mechanisms at once on a churning fleet — heap
    // core == reference loop on results, metrics, and the full flight
    // recorder, and the strict-versioned trace round-trips through
    // `replay` reconstructing every resilience counter.
    {
        let base = ClusterConfig::with_devices(8)
            .capacity(2)
            .max_queue(8)
            .backlog(64)
            .policy(ShardPolicy::LeastLoaded)
            .shed_late(true)
            .hedge(HedgePolicy::quantile(0.95))
            .brownout(BrownoutConfig::new(0.9, 24, 2, 0.5));
        let costs = profile_step_costs(&base).expect("parity fleet must price");
        let schedule = NoiseSchedule::linear(1000);
        let reqs = synthetic_workload(96, 37, SamplerKind::Ddim { steps: 8 }, 1e-5);
        let mut probe = StepScheduler::new(&base, &costs, schedule.clone(), 256);
        let mp = probe
            .serve(reqs.clone(), &mut SimExecutor)
            .expect("parity probe")
            .metrics
            .makespan_s;
        let plan = FaultPlan::new()
            .crash_at(0.2 * mp, 1)
            .outage_at(0.35 * mp, 3, 0.15 * mp)
            .slow_at(0.1 * mp, 5, 2.5);
        let cfg = base.faults(plan);
        let src = || {
            RequestSource::replay(reqs.clone())
                .with_slos(vec![0.5 * mp, 0.1 * mp])
                .with_retry(RetryPolicy::new(3, 0.02 * mp, 1.0), 11)
        };
        let mut heap = StepScheduler::new(&cfg, &costs, schedule.clone(), 256);
        heap.set_trace(TraceSink::new());
        let a = heap.serve_source(src(), &mut SimExecutor).expect("heap serve");
        let ta = heap.take_trace().expect("heap trace");
        let mut reference = ReferenceScheduler::new(&cfg, &costs, schedule, 256);
        reference.set_trace(TraceSink::new());
        let b = reference.serve_source(src(), &mut SimExecutor).expect("reference serve");
        let tb = reference.take_trace().expect("reference trace");
        assert_eq!(a.metrics, b.metrics, "resilience parity: metrics diverged");
        assert_eq!(a.rejected, b.rejected, "resilience parity: rejection set diverged");
        assert_eq!(a.results.len(), b.results.len());
        for (ra, rb) in a.results.iter().zip(&b.results) {
            assert_eq!((ra.id, ra.device), (rb.id, rb.device), "resilience parity: placement");
            assert_eq!(ra.sample, rb.sample, "resilience parity: samples");
            assert!(ra.finish_s == rb.finish_s, "resilience parity: timings");
        }
        assert_eq!(ta.to_jsonl(), tb.to_jsonl(), "resilience parity: traces diverged");
        let parsed =
            parse_jsonl_versioned(&ta.to_jsonl()).expect("versioned trace must round-trip");
        let rep = replay(&parsed);
        assert_eq!(rep.metrics.rejected, a.metrics.rejected, "replay: rejected");
        assert_eq!(rep.metrics.hedged(), a.metrics.hedged(), "replay: hedged");
        assert_eq!(rep.metrics.cancelled(), a.metrics.cancelled(), "replay: cancelled");
        assert_eq!(rep.metrics.retries(), a.metrics.retries(), "replay: retries");
        assert_eq!(rep.metrics.degraded(), a.metrics.degraded(), "replay: degraded");
        assert_eq!(rep.metrics.lost(), a.metrics.lost(), "replay: lost");
        println!(
            "resilience parity gate: heap == reference with retry+hedge+brownout enabled \
             ({} trace events, bit-identical; replay rebuilds every counter)",
            parsed.len()
        );
    }

    // ---- (i) fleet-composition DSE: stacked perf layers vs the oracle ----
    // The exhaustive sequential unpruned sweep is the quality oracle and
    // the perf yardstick; the production path stacks parallel fan-out, the
    // fleet-sim memo (persistent across sweeps — harness warmup populates
    // it, so the timed iterations measure the memoized steady state the
    // way re-sweeps hit it) and successive-halving pruning. A separate
    // one-shot cold timing isolates parallel+pruning without the memo.
    let fleet_dse_full = !smoke || std::env::args().any(|a| a == "--fleet-dse");
    let (fd_budget_dies, fd_requests, fd_steps) =
        if fleet_dse_full { (8usize, 96usize, 8usize) } else { (2, 32, 4) };
    let (fd_rungs, fd_keep, fd_target) = (3usize, 0.5f64, 0.99f64);
    let fd_space = FleetSpace::paper(fd_budget_dies * FleetSpace::paper_die_mrs());
    let fd_candidates = fd_space.candidates().len();
    let fd_trace = FleetTrace::synthetic(
        fd_requests,
        11,
        SamplerKind::Ddim { steps: fd_steps },
        2e-4,
        vec![2e-3, 1e-2],
    );
    let fd_knobs = FleetKnobs::default();
    harness::section(&format!(
        "fleet DSE ({}): {fd_candidates} candidates under a {fd_budget_dies}-die MR budget, \
         {fd_requests}-request trace, {threads} threads",
        if fleet_dse_full { "full" } else { "smoke" }
    ));
    let fd_iters = if fleet_dse_full { 3 } else { 1 };
    let mut fd_oracle = None;
    let fd_unpruned = harness::bench("explore_fleet_unpruned (sequential, no memo)", fd_iters, || {
        fd_oracle = Some(harness::black_box(explore_fleet_unpruned(
            &fd_space, &fd_trace, &fd_knobs, fd_target,
        )));
    });
    // Cold one-shot: fresh memo, so this is parallel+pruning alone.
    let fd_cold_memo = Arc::new(FleetMemo::new());
    let fd_t0 = Instant::now();
    harness::black_box(explore_fleet(
        &fd_space, &fd_trace, &fd_knobs, fd_target, fd_rungs, fd_keep, threads, &fd_cold_memo,
    ));
    let fd_cold_s = fd_t0.elapsed().as_secs_f64();
    // Steady state: the memo persists across iterations (and warmup).
    let fd_memo = Arc::new(FleetMemo::new());
    let fd_step_before = cache_for_width(8).stats();
    let mut fd_points = None;
    let fd_pruned = harness::bench("explore_fleet (parallel+memoized+pruned)", fd_iters, || {
        fd_points = Some(harness::black_box(explore_fleet(
            &fd_space, &fd_trace, &fd_knobs, fd_target, fd_rungs, fd_keep, threads, &fd_memo,
        )));
    });
    let fd_step_cache = cache_for_width(8).stats().delta(&fd_step_before);
    let fd_speedup = fd_unpruned.mean_s / fd_pruned.mean_s;
    let fd_cold_speedup = fd_unpruned.mean_s / fd_cold_s;
    let fd_oracle = fd_oracle.expect("bench ran");
    let fd_points = fd_points.expect("bench ran");
    assert!(!fd_oracle.is_empty() && !fd_points.is_empty(), "fleet sweeps must score");
    let fd_best = fd_oracle[0].objective;
    let fd_got = fd_points[0].objective;
    let fd_gap = 1.0 - fd_got / fd_best;
    println!(
        "fleet DSE: pruned winner {} ({:.3e} samples/J) vs oracle {} ({:.3e}), gap {:.2}%",
        fd_points[0].spec,
        fd_got,
        fd_oracle[0].spec,
        fd_best,
        100.0 * fd_gap,
    );
    println!(
        "fleet DSE speedup: {fd_speedup:.1}x memoized steady state, {fd_cold_speedup:.1}x cold \
         (parallel+pruning only); step cache saw {} hits / {} misses",
        fd_step_cache.hits, fd_step_cache.misses,
    );
    // Quality gate (always): the pruned winner lands within 2% of the
    // unpruned optimum's goodput/J objective.
    assert!(
        fd_got >= 0.98 * fd_best,
        "pruned fleet winner must be within 2% of the unpruned optimum \
         (got {fd_got:.3e} vs {fd_best:.3e})"
    );
    // Bit-identity gate (always): every final-rung survivor was scored on
    // the full trace through the memo, so it must match the uncached
    // oracle's evaluation of the same spec bit for bit.
    for p in &fd_points {
        let o = fd_oracle
            .iter()
            .find(|o| o.spec == p.spec)
            .expect("oracle covers every candidate");
        assert_eq!(
            (
                p.goodput_samples_per_s.to_bits(),
                p.attainment.to_bits(),
                p.energy_j.to_bits(),
                p.objective.to_bits(),
            ),
            (
                o.goodput_samples_per_s.to_bits(),
                o.attainment.to_bits(),
                o.energy_j.to_bits(),
                o.objective.to_bits(),
            ),
            "memoized fleet evaluation must be bit-identical to uncached ({})",
            p.spec
        );
    }
    // Memo gate (always): a re-sweep through the same memo re-simulates
    // nothing and returns the identical ranking.
    let fd_warm_before = fd_memo.stats();
    let fd_again = explore_fleet(
        &fd_space, &fd_trace, &fd_knobs, fd_target, fd_rungs, fd_keep, threads, &fd_memo,
    );
    let fd_warm = fd_memo.stats().delta(&fd_warm_before);
    assert!(
        fd_warm.hits > 0 && fd_warm.misses == 0,
        "fleet-memo re-sweep must be pure hits (saw {} hits / {} misses)",
        fd_warm.hits,
        fd_warm.misses
    );
    assert_eq!(fd_points.len(), fd_again.len());
    for (a, b) in fd_points.iter().zip(&fd_again) {
        assert_eq!(a.spec, b.spec, "memoized re-sweep must preserve the ranking");
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
    }
    println!(
        "fleet memo: {} entries, re-sweep {} hits / 0 misses",
        fd_warm.entries, fd_warm.hits
    );
    // Perf gate (full mode; host timing, so not asserted in smoke): the
    // production path clears 5x over the sequential unpruned sweep.
    if fleet_dse_full {
        assert!(
            fd_speedup >= 5.0,
            "parallel+memoized+pruned fleet sweep must be >= 5x the sequential \
             unpruned baseline (got {fd_speedup:.1}x)"
        );
    }

    // ---- record the trajectory ----
    let report = Json::obj()
        .set("bench", "sim_hot_path")
        .set("mode", mode)
        .set("threads", threads)
        .set(
            "dse",
            Json::obj()
                .set("candidates", candidates)
                .set("iters", iters)
                .set("uncached_s", uncached.mean_s)
                .set("cached_s", cached.mean_s)
                .set("speedup", speedup)
                .set("bit_identical", true)
                .set(
                    "cache",
                    Json::obj()
                        .set("hits", cs.hits)
                        .set("misses", cs.misses)
                        .set("layer_entries", cs.layer_entries)
                        .set("step_entries", cs.step_entries),
                ),
        )
        .set(
            "cluster",
            Json::obj()
                .set("requests", requests)
                .set("steps", steps)
                .set("devices", devices)
                .set("no_reuse", cluster_json(&k1, k1_host))
                .set("reuse_k3", cluster_json(&k3, k3_host))
                .set("throughput_ratio", ratio),
        )
        .set(
            "fleet_scale",
            Json::obj()
                .set("steps", harness::FLEET_SCALE_STEPS)
                .set("reqs_per_device", harness::FLEET_SCALE_REQS_PER_DEVICE)
                .set("sweep", Json::Arr(scale_sweep))
                .set("top_devices", top_devices)
                .set("speedup_at_top", top_speedup)
                .set(
                    "layout",
                    Json::obj()
                        .set("devices", layout_devices)
                        .set("legacy_events_per_s", legacy_eps)
                        .set("arena_events_per_s", arena_eps)
                        .set("speedup", layout_speedup),
                )
                .set(
                    "shard_sweep",
                    Json::obj()
                        .set("elems", harness::SHARD_SWEEP_ELEMS)
                        .set("steps", harness::SHARD_SWEEP_STEPS)
                        .set("reqs_per_device", harness::SHARD_SWEEP_REQS_PER_DEVICE)
                        .set("sweep", Json::Arr(shard_sweep))
                        .set("top_devices", top_shard_devices)
                        .set("top_shards", top_shard_count)
                        .set("speedup_at_top", top_shard_speedup)
                        .set("workers", workers)
                        .set("gate_enforced", shard_gate_enforced),
                ),
        )
        .set(
            "fleet_hetero",
            Json::obj()
                .set("requests", h_requests)
                .set("steps", h_steps)
                .set("work_stealing", false)
                .set(
                    "big",
                    Json::obj()
                        .set("arch", format!("{:?}", harness::HETERO_BIG_ARCH))
                        .set("count", harness::HETERO_BIG_COUNT),
                )
                .set(
                    "small",
                    Json::obj()
                        .set("arch", format!("{:?}", harness::HETERO_SMALL_ARCH))
                        .set("count", harness::HETERO_SMALL_COUNT),
                )
                .set("mixed_mrs", mixed_mrs)
                .set("homogeneous_mrs", homog_mrs)
                .set("cost_aware", cluster_json(&aware, aware_host))
                .set("occupancy_only", cluster_json(&blind, blind_host))
                .set("homogeneous_equal_area", cluster_json(&homog, homog_host))
                .set("routing_gain", routing_gain)
                .set("parity_bit_identical", true),
        )
        .set(
            "slo_knee",
            Json::obj()
                .set("devices", harness::SLO_DEVICES)
                .set("capacity", harness::SLO_CAPACITY)
                .set("max_queue", harness::SLO_MAX_QUEUE)
                .set("steps", harness::SLO_STEPS)
                .set("requests", knee_requests)
                .set("slo_ms", slo_s * 1e3)
                .set("fleet_rate_rps", fleet_rate)
                .set("sweep", Json::Arr(knee_sweep))
                .set("knee_rate_rps", knee_rate)
                .set(
                    "overload",
                    Json::obj()
                        .set("rate_rps", overload_rate)
                        .set(
                            "shed_late",
                            Json::obj()
                                .set("goodput_samples_per_s", kept.metrics.goodput_samples_per_s())
                                .set("attainment", kept.metrics.slo_attainment())
                                .set("shed", kept.shed()),
                        )
                        .set(
                            "shed_on_full",
                            Json::obj()
                                .set("goodput_samples_per_s", full.metrics.goodput_samples_per_s())
                                .set("attainment", full.metrics.slo_attainment())
                                .set("shed", full.shed()),
                        )
                        .set("goodput_gain", goodput_gain),
                )
                .set("closed_loop_parity_bit_identical", true),
        )
        .set(
            "obs",
            Json::obj()
                .set("quantiles", Json::Arr(obs_quantiles))
                .set(
                    "recorder",
                    Json::obj()
                        .set("devices", 64usize)
                        .set("events", rec_events)
                        .set("plain_events_per_s", plain_eps)
                        .set("traced_events_per_s", traced_eps)
                        .set("overhead_frac", overhead),
                )
                .set(
                    "memory",
                    Json::obj()
                        .set("samples_1x", mem_1x.results.len())
                        .set("hist_bytes_1x", bytes_1x)
                        .set("samples_10x", mem_10x.results.len())
                        .set("hist_bytes_10x", bytes_10x)
                        .set("growth", growth),
                )
                .set(
                    "replay",
                    Json::obj().set("events", replay_events).set("bit_identical", true),
                ),
        )
        .set(
            "resilience",
            Json::obj()
                .set("devices", res_devices)
                .set("requests", res_requests)
                .set("crashed", crash_n)
                .set("baseline_goodput_samples_per_s", baseline_goodput)
                .set(
                    "degraded",
                    Json::obj()
                        .set("goodput_ratio", goodput_ratio)
                        .set("interrupted", degraded.metrics.interrupted())
                        .set("migrated", degraded.metrics.migrated())
                        .set("retried", degraded.metrics.retried())
                        .set("lost", degraded.metrics.lost())
                        .set("downtime_s", degraded.metrics.downtime_s()),
                )
                .set("ablation_lost", ablation_lost)
                .set("parity_bit_identical", true)
                .set("sweep", Json::Arr(res_sweep)),
        )
        .set(
            "brownout",
            Json::obj()
                .set("devices", bo_devices)
                .set("requests", bo_requests)
                .set("gen_s", gen_s)
                .set("capacity_samples_per_s", bo_cap_rate)
                .set("overload_rate_rps", bo_rate)
                .set(
                    "degraded_tiers",
                    Json::obj()
                        .set("goodput_samples_per_s", bo_g_deg)
                        .set("shed_only_goodput_samples_per_s", bo_g_shed)
                        .set("goodput_gain", bo_gain)
                        .set("degraded_admissions", bo_browned.metrics.degraded())
                        .set("top_class_attainment", bo_top),
                )
                .set(
                    "hedge",
                    Json::obj()
                        .set("requests", hg_requests)
                        .set("p99_clean_s", hg_p99_clean)
                        .set("p99_straggler_s", hg_p99_slow)
                        .set("p99_hedged_s", hg_p99_hedged)
                        .set("regression_recovered", hg_recovery)
                        .set("hedged", hg_hedged.metrics.hedged())
                        .set("cancelled", hg_hedged.metrics.cancelled())
                        .set("duplicate_work_frac", hg_dup_frac),
                )
                .set(
                    "retry",
                    Json::obj()
                        .set("requests", rt_requests)
                        .set("ablation_lost", rt_without.metrics.lost())
                        .set("retries", rt_with.metrics.retries())
                        .set("lost", rt_with.metrics.lost())
                        .set("served", rt_with.results.len()),
                )
                .set("parity_bit_identical", true),
        )
        .set(
            "fleet_dse",
            Json::obj()
                .set("candidates", fd_candidates)
                .set("budget_dies", fd_budget_dies)
                .set("trace_requests", fd_requests)
                .set("steps", fd_steps)
                .set("rungs", fd_rungs)
                .set("keep", fd_keep)
                .set("slo_target", fd_target)
                .set("iters", fd_iters)
                .set("threads", threads)
                .set("unpruned_s", fd_unpruned.mean_s)
                .set("pruned_cold_s", fd_cold_s)
                .set("pruned_memoized_s", fd_pruned.mean_s)
                .set("speedup", fd_speedup)
                .set("cold_speedup", fd_cold_speedup)
                .set("gate_enforced", fleet_dse_full)
                .set("winner", fd_points[0].spec.clone())
                .set("winner_objective", fd_got)
                .set("oracle_winner", fd_oracle[0].spec.clone())
                .set("oracle_objective", fd_best)
                .set("winner_gap", fd_gap)
                .set("bit_identical", true)
                .set(
                    "memo",
                    Json::obj()
                        .set("entries", fd_warm.entries)
                        .set("resweep_hits", fd_warm.hits)
                        .set("resweep_misses", fd_warm.misses),
                )
                .set(
                    "step_cache",
                    Json::obj()
                        .set("hits", fd_step_cache.hits)
                        .set("misses", fd_step_cache.misses)
                        .set("step_entries", fd_step_cache.step_entries),
                ),
        );
    let path = "BENCH_sim.json";
    std::fs::write(path, report.to_string_pretty()).expect("write bench report");
    println!("\nwrote {path}");
}
