//! Fleet-composition search: which fleet should you build for this
//! traffic?
//!
//! The die-level DSE ([`super::search`]) picks the best `[Y,N,K,H,L,M]`
//! vector by GOPS/EPB in isolation. This module answers the ROADMAP's
//! composition question: given a fixed traffic trace and a total-MR
//! silicon budget, which *mix* of dies (a [`FleetSpace`] candidate —
//! profile groups × counts) serves it best? Candidates are ranked by
//! **goodput per joule** — good (SLO-met, un-shed) samples completed
//! over the run divided by the fleet energy drawn — scaled down when
//! the fleet misses the target SLO attainment.
//!
//! Each candidate costs a full discrete-event simulation, so the sweep
//! stacks three perf layers:
//!
//! 1. **Parallel evaluation** — candidates fan out over
//!    [`ThreadPool::map`], one [`Cluster`] per evaluation. Workers share
//!    the process-wide per-bit-width step memo
//!    ([`crate::cluster::cache_for_width`]), so sibling candidates never
//!    re-price a profile's step cost.
//! 2. **A fleet-sim memo** ([`FleetMemo`]) — keyed by the *canonical*
//!    fleet key ([`fleet_spec_key`]: duplicate groups merged, groups
//!    sorted), the trace id, the effective prefix length, the scheduler
//!    knobs and the attainment target. Permuted or split-group
//!    duplicates of a candidate, and re-sweeps over the same trace, hit
//!    instead of re-simulating. Memoized results are bit-identical to
//!    uncached evaluation (the memo stores, never recomputes).
//! 3. **Successive-halving pruning** ([`explore_fleet`]) — rung `r` of
//!    `R` scores survivors on the first [`rung_prefix`] requests of the
//!    trace (half the trace at the penultimate rung, a quarter before
//!    that, …), keeps the top `keep` fraction, and only runs the final
//!    rung on the full trace. The exhaustive sweep is kept as
//!    [`explore_fleet_unpruned`] — the quality oracle (the pruned winner
//!    must land within 2% of its optimum on the bench workload) and the
//!    perf baseline. The final rung's memo key is exactly the
//!    full-trace key, so a pruned sweep's winners seed later unpruned
//!    or re-run sweeps.
//!
//! Ties (equal objectives) order by canonical spec string, so rankings
//! are stable across runs and thread counts.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::cluster::{
    apply_slos, fleet_spec_key, merge_duplicate_groups, synthetic_workload, Cluster,
    ClusterConfig, ClusterRequest, DeviceProfile, RequestSource, ShardPolicy, SimExecutor,
};
use crate::coordinator::request::SamplerKind;
use crate::util::fxhash::{fx_hash_one, FxMap};
use crate::util::threadpool::ThreadPool;
use crate::workload::ModelId;

use super::space::FleetSpace;

/// A fixed traffic trace with a stable identity for memo keys.
///
/// The id hashes every generation parameter (count, seed, sampler,
/// arrival process, SLO ladder), so two traces with the same id carry
/// bit-identical requests within one process.
#[derive(Debug, Clone)]
pub struct FleetTrace {
    pub id: u64,
    pub requests: Vec<ClusterRequest>,
    /// Per-class latency SLOs (empty = best-effort traffic).
    pub slos_s: Vec<f64>,
}

impl FleetTrace {
    /// A synthetic Poisson trace ([`synthetic_workload`]) with the SLO
    /// ladder applied round-robin by request id ([`apply_slos`]).
    pub fn synthetic(
        n: usize,
        seed: u64,
        sampler: SamplerKind,
        mean_gap_s: f64,
        slos_s: Vec<f64>,
    ) -> Self {
        let mut requests = synthetic_workload(n, seed, sampler, mean_gap_s);
        apply_slos(&mut requests, &slos_s);
        let sampler_code = match sampler {
            SamplerKind::Ddpm => 1u64 << 32,
            SamplerKind::Ddim { steps } => steps as u64,
        };
        let mut enc: Vec<u64> = vec![n as u64, seed, sampler_code, mean_gap_s.to_bits()];
        enc.extend(slos_s.iter().map(|s| s.to_bits()));
        Self { id: fx_hash_one(&enc), requests, slos_s }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Scheduler knobs held fixed across one sweep — part of the memo key,
/// because the same fleet under a different router or backlog policy is
/// a different simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetKnobs {
    pub model: ModelId,
    pub policy: ShardPolicy,
    /// Shed requests that cannot meet their deadline at admission
    /// (only applied when the trace carries SLOs).
    pub shed_late: bool,
    pub max_backlog: usize,
}

impl Default for FleetKnobs {
    fn default() -> Self {
        Self {
            model: ModelId::DdpmCifar10,
            policy: ShardPolicy::default(),
            shed_late: true,
            max_backlog: 0,
        }
    }
}

impl FleetKnobs {
    /// Canonical memo-key fragment.
    pub fn key(&self) -> String {
        format!(
            "{:?}|{:?}|shed{}|bl{}",
            self.model, self.policy, self.shed_late as u8, self.max_backlog
        )
    }
}

/// One evaluated fleet candidate.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetPoint {
    /// The canonical (merged) fleet spec this point was simulated with.
    pub fleet: Vec<(DeviceProfile, usize)>,
    /// Canonical key ([`fleet_spec_key`]) — the tie-break and memo id.
    pub spec: String,
    pub devices: usize,
    /// Silicon footprint across the fleet (total MRs).
    pub total_mrs: usize,
    /// Good (SLO-met, un-shed) samples per second over the run.
    pub goodput_samples_per_s: f64,
    /// SLO attainment over tracked requests (sheds count as misses);
    /// 0.0 on best-effort traffic.
    pub attainment: f64,
    /// Total fleet energy drawn over the run, joules.
    pub energy_j: f64,
    /// The figure of merit: good samples per joule, scaled by
    /// `min(1, attainment/target)` when the trace carries SLOs.
    pub objective: f64,
}

/// The number of trace requests rung `rung` of `rungs` evaluates:
/// the full trace at the final rung, halving per rung below it, floored
/// at 8 requests (or the whole trace when it is shorter than that).
pub fn rung_prefix(trace_len: usize, rungs: usize, rung: usize) -> usize {
    if rung + 1 >= rungs {
        return trace_len;
    }
    (trace_len >> (rungs - 1 - rung)).max(8.min(trace_len))
}

/// Simulate one fleet candidate on the first `prefix_len` requests of
/// `trace` (saturating at the trace length) and score it. Returns `None`
/// when the fleet cannot be built (e.g. a die violating design rules).
pub fn evaluate_fleet(
    fleet: &[(DeviceProfile, usize)],
    trace: &FleetTrace,
    prefix_len: usize,
    knobs: &FleetKnobs,
    target_attainment: f64,
) -> Option<FleetPoint> {
    let fleet = merge_duplicate_groups(fleet.to_vec());
    let spec = fleet_spec_key(&fleet);
    let total_mrs = FleetSpace::fleet_mrs(&fleet);
    let mut cfg = ClusterConfig::heterogeneous(fleet.clone());
    cfg.model = knobs.model;
    cfg.policy = knobs.policy;
    cfg.max_backlog = knobs.max_backlog;
    cfg.shed_late = knobs.shed_late && !trace.slos_s.is_empty();
    let devices = cfg.device_count();
    let mut cluster = Cluster::simulated(cfg).ok()?;
    let source = RequestSource::replay_prefix(&trace.requests, prefix_len);
    let out = cluster.serve_source(source, &mut SimExecutor).ok()?;
    let m = &out.metrics;
    let goodput = m.goodput_samples_per_s();
    let energy_j = m.total_energy_j();
    let attainment = m.slo_attainment();
    // Good samples completed over the run; invariant to trace length,
    // so rung scores on different prefixes stay comparable.
    let good_samples = goodput * m.makespan_s;
    let mut objective = if energy_j > 0.0 { good_samples / energy_j } else { 0.0 };
    if !trace.slos_s.is_empty() && target_attainment > 0.0 {
        objective *= (attainment / target_attainment).min(1.0);
    }
    Some(FleetPoint {
        fleet,
        spec,
        devices,
        total_mrs,
        goodput_samples_per_s: goodput,
        attainment,
        energy_j,
        objective,
    })
}

#[derive(Clone, PartialEq, Eq, Hash)]
struct FleetMemoKey {
    /// Canonical fleet key — permutation/grouping invariant.
    spec: String,
    trace_id: u64,
    /// Effective prefix (`min(prefix_len, trace.len())`), so a
    /// final-rung evaluation and a direct full-trace evaluation share
    /// one entry.
    prefix: usize,
    knobs: String,
    target_bits: u64,
}

/// Hit/miss/size snapshot of a [`FleetMemo`] (the fleet-level analogue
/// of [`crate::sim::CacheStats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FleetMemoStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

impl FleetMemoStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Traffic since `earlier` (saturating counter deltas, current
    /// entry count) — mirrors [`crate::sim::CacheStats::delta`].
    pub fn delta(&self, earlier: &FleetMemoStats) -> FleetMemoStats {
        FleetMemoStats {
            hits: self.hits.saturating_sub(earlier.hits),
            misses: self.misses.saturating_sub(earlier.misses),
            entries: self.entries,
        }
    }
}

/// The fleet-sim memo: canonical candidate key → evaluated point.
/// Thread-safe; one instance is shared by every worker of a sweep (and
/// across sweeps, when the caller keeps it alive). Unbuildable fleets
/// memoize their `None` too, so repeated rejects stay cheap.
#[derive(Default)]
pub struct FleetMemo {
    map: RwLock<FxMap<FleetMemoKey, Option<FleetPoint>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl FleetMemo {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn stats(&self) -> FleetMemoStats {
        FleetMemoStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.map.read().expect("memo lock").len(),
        }
    }
}

/// [`evaluate_fleet`] through the memo: permuted/duplicate specs and
/// repeated evaluations return the cached point (bit-identical — the
/// memo only ever stores what [`evaluate_fleet`] produced).
pub fn evaluate_fleet_memo(
    fleet: &[(DeviceProfile, usize)],
    trace: &FleetTrace,
    prefix_len: usize,
    knobs: &FleetKnobs,
    target_attainment: f64,
    memo: &FleetMemo,
) -> Option<FleetPoint> {
    let key = FleetMemoKey {
        spec: fleet_spec_key(fleet),
        trace_id: trace.id,
        prefix: prefix_len.min(trace.requests.len()),
        knobs: knobs.key(),
        target_bits: target_attainment.to_bits(),
    };
    if let Some(p) = memo.map.read().expect("memo lock").get(&key) {
        memo.hits.fetch_add(1, Ordering::Relaxed);
        return p.clone();
    }
    // Concurrent misses on the same key simulate the same bits, so
    // racing inserts are benign (same value).
    memo.misses.fetch_add(1, Ordering::Relaxed);
    let p = evaluate_fleet(fleet, trace, prefix_len, knobs, target_attainment);
    memo.map.write().expect("memo lock").insert(key, p.clone());
    p
}

/// Sort best-first: objective descending, NaN after real scores, and
/// equal objectives ordered by canonical spec string so rankings are
/// deterministic across runs and thread counts.
pub fn sort_fleet_points(points: &mut [FleetPoint]) {
    points.sort_by(|a, b| match (a.objective.is_nan(), b.objective.is_nan()) {
        (false, false) => {
            b.objective.total_cmp(&a.objective).then_with(|| a.spec.cmp(&b.spec))
        }
        (true, true) => a.spec.cmp(&b.spec),
        (true, false) => std::cmp::Ordering::Greater, // NaN after real scores
        (false, true) => std::cmp::Ordering::Less,
    });
}

/// Successive-halving sweep over `space`: rung `r` evaluates the
/// surviving candidates on [`rung_prefix`] requests across `threads`
/// workers (all through `memo`), keeps the top `keep` fraction (at
/// least one), and the final rung scores survivors on the full trace.
/// Returns the final rung's points, best first.
pub fn explore_fleet(
    space: &FleetSpace,
    trace: &FleetTrace,
    knobs: &FleetKnobs,
    target_attainment: f64,
    rungs: usize,
    keep: f64,
    threads: usize,
    memo: &Arc<FleetMemo>,
) -> Vec<FleetPoint> {
    let rungs = rungs.max(1);
    let keep = if keep.is_finite() { keep.clamp(0.05, 1.0) } else { 0.5 };
    let pool = ThreadPool::new(threads.max(1));
    let trace = Arc::new(trace.clone());
    let len = trace.len();
    let mut survivors = space.candidates();
    for rung in 0..rungs {
        let prefix = rung_prefix(len, rungs, rung);
        let tr = Arc::clone(&trace);
        let kn = knobs.clone();
        let mm = Arc::clone(memo);
        let mut points: Vec<FleetPoint> = pool
            .map(survivors, move |fleet| {
                evaluate_fleet_memo(&fleet, &tr, prefix, &kn, target_attainment, &mm)
            })
            .into_iter()
            .flatten()
            .collect();
        sort_fleet_points(&mut points);
        if rung + 1 == rungs {
            return points;
        }
        let keep_n = ((points.len() as f64 * keep).ceil() as usize).max(1);
        points.truncate(keep_n);
        survivors = points.into_iter().map(|p| p.fleet).collect();
    }
    Vec::new()
}

/// The exhaustive baseline: every candidate on the full trace,
/// sequentially, with no memo. Quality oracle and perf yardstick for
/// [`explore_fleet`].
pub fn explore_fleet_unpruned(
    space: &FleetSpace,
    trace: &FleetTrace,
    knobs: &FleetKnobs,
    target_attainment: f64,
) -> Vec<FleetPoint> {
    let mut points: Vec<FleetPoint> = space
        .candidates()
        .iter()
        .filter_map(|f| evaluate_fleet(f, trace, usize::MAX, knobs, target_attainment))
        .collect();
    sort_fleet_points(&mut points);
    points
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_trace() -> FleetTrace {
        FleetTrace::synthetic(24, 11, SamplerKind::Ddim { steps: 4 }, 2e-4, vec![0.002, 0.01])
    }

    fn small_space() -> FleetSpace {
        let mut s = FleetSpace::paper(4 * FleetSpace::paper_die_mrs());
        s.counts = vec![0, 1, 2];
        s
    }

    fn bits(p: &FleetPoint) -> [u64; 4] {
        [
            p.goodput_samples_per_s.to_bits(),
            p.attainment.to_bits(),
            p.energy_j.to_bits(),
            p.objective.to_bits(),
        ]
    }

    #[test]
    fn trace_id_is_a_parameter_fingerprint() {
        let a = small_trace();
        assert_eq!(a.id, small_trace().id, "same params, same id");
        let b = FleetTrace::synthetic(24, 12, SamplerKind::Ddim { steps: 4 }, 2e-4, vec![0.002, 0.01]);
        let c = FleetTrace::synthetic(24, 11, SamplerKind::Ddim { steps: 4 }, 2e-4, vec![]);
        assert_ne!(a.id, b.id, "seed must change the id");
        assert_ne!(a.id, c.id, "SLO ladder must change the id");
        assert_eq!(a.len(), 24);
        assert!(a.requests.iter().all(|r| r.deadline_s.is_some()));
    }

    #[test]
    fn memoized_evaluation_bit_identical_to_uncached() {
        let trace = small_trace();
        let knobs = FleetKnobs::default();
        let fleet = vec![(DeviceProfile::default(), 2)];
        let want = evaluate_fleet(&fleet, &trace, usize::MAX, &knobs, 0.99).expect("evaluates");
        let memo = FleetMemo::new();
        let cold = evaluate_fleet_memo(&fleet, &trace, usize::MAX, &knobs, 0.99, &memo)
            .expect("evaluates");
        assert_eq!(bits(&cold), bits(&want), "memoized must be bit-identical to uncached");
        assert_eq!(cold.spec, want.spec);
        let warm = evaluate_fleet_memo(&fleet, &trace, usize::MAX, &knobs, 0.99, &memo)
            .expect("evaluates");
        assert_eq!(bits(&warm), bits(&want));
        let s = memo.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
    }

    #[test]
    fn memo_hits_on_permuted_split_and_full_prefix_aliases() {
        let trace = small_trace();
        let knobs = FleetKnobs::default();
        let a = DeviceProfile::default();
        let b = DeviceProfile::with_capacity(2, 16);
        let memo = FleetMemo::new();
        let base = evaluate_fleet_memo(&[(a, 1), (b, 2)], &trace, usize::MAX, &knobs, 0.99, &memo)
            .expect("evaluates");
        // Permuted, split-group, and over-length-prefix spellings of the
        // same candidate all alias to the one entry.
        for fleet in [vec![(b, 2), (a, 1)], vec![(a, 1), (b, 1), (b, 1)]] {
            let again = evaluate_fleet_memo(&fleet, &trace, trace.len(), &knobs, 0.99, &memo)
                .expect("evaluates");
            assert_eq!(bits(&again), bits(&base));
        }
        let s = memo.stats();
        assert_eq!((s.hits, s.misses, s.entries), (2, 1, 1));
        // A different prefix is a different simulation: miss.
        evaluate_fleet_memo(&[(a, 1), (b, 2)], &trace, 12, &knobs, 0.99, &memo);
        assert_eq!(memo.stats().misses, 2);
    }

    #[test]
    fn rung_schedule_halves_down_to_the_floor() {
        assert_eq!(rung_prefix(64, 3, 0), 16);
        assert_eq!(rung_prefix(64, 3, 1), 32);
        assert_eq!(rung_prefix(64, 3, 2), 64);
        assert_eq!(rung_prefix(64, 1, 0), 64);
        // The floor: tiny prefixes clamp to 8 requests…
        assert_eq!(rung_prefix(64, 5, 0), 8);
        // …or the whole trace when it is shorter than that.
        assert_eq!(rung_prefix(6, 3, 0), 6);
    }

    #[test]
    fn pruned_search_matches_unpruned_oracle_on_small_space() {
        let space = small_space();
        let trace = small_trace();
        let knobs = FleetKnobs::default();
        let oracle = explore_fleet_unpruned(&space, &trace, &knobs, 0.99);
        assert!(!oracle.is_empty());
        let memo = Arc::new(FleetMemo::new());
        let pruned = explore_fleet(&space, &trace, &knobs, 0.99, 2, 0.75, 2, &memo);
        assert!(!pruned.is_empty());
        let best = oracle[0].objective;
        assert!(best > 0.0, "oracle optimum must score");
        assert!(
            pruned[0].objective >= 0.98 * best,
            "pruned winner {} must be within 2% of unpruned optimum {}",
            pruned[0].objective,
            best
        );
        // Final-rung survivors were scored on the full trace, so their
        // objectives are bit-identical to the oracle's for the same spec.
        for p in &pruned {
            let o = oracle.iter().find(|o| o.spec == p.spec).expect("oracle covers the space");
            assert_eq!(bits(p), bits(o));
        }
        assert!(memo.stats().misses > 0);
    }

    #[test]
    fn sweep_is_deterministic_across_thread_counts() {
        let space = small_space();
        let trace = small_trace();
        let knobs = FleetKnobs::default();
        let one = explore_fleet(&space, &trace, &knobs, 0.99, 2, 0.75, 1, &Arc::new(FleetMemo::new()));
        let four = explore_fleet(&space, &trace, &knobs, 0.99, 2, 0.75, 4, &Arc::new(FleetMemo::new()));
        assert_eq!(one.len(), four.len());
        for (a, b) in one.iter().zip(four.iter()) {
            assert_eq!(a.spec, b.spec);
            assert_eq!(bits(a), bits(b));
        }
    }

    #[test]
    fn resweep_through_a_shared_memo_is_all_hits() {
        let space = small_space();
        let trace = small_trace();
        let knobs = FleetKnobs::default();
        let memo = Arc::new(FleetMemo::new());
        let first = explore_fleet(&space, &trace, &knobs, 0.99, 2, 0.75, 2, &memo);
        let cold = memo.stats();
        assert_eq!(cold.hits, 0, "fresh memo, cold sweep");
        let second = explore_fleet(&space, &trace, &knobs, 0.99, 2, 0.75, 2, &memo);
        let warm = memo.stats().delta(&cold);
        assert_eq!(warm.misses, 0, "re-sweep must not re-simulate");
        assert!(warm.hits > 0);
        assert_eq!(first.len(), second.len());
        for (a, b) in first.iter().zip(second.iter()) {
            assert_eq!((a.spec.as_str(), bits(a)), (b.spec.as_str(), bits(b)));
        }
    }

    #[test]
    fn ties_order_by_spec_and_nan_sorts_last() {
        let mk = |spec: &str, objective: f64| FleetPoint {
            fleet: Vec::new(),
            spec: spec.to_string(),
            devices: 1,
            total_mrs: 1,
            goodput_samples_per_s: 1.0,
            attainment: 1.0,
            energy_j: 1.0,
            objective,
        };
        let mut pts = vec![
            mk("c", 2.0),
            mk("b", f64::NAN),
            mk("a", 2.0),
            mk("d", 5.0),
            mk("e", f64::NAN),
        ];
        sort_fleet_points(&mut pts);
        let order: Vec<&str> = pts.iter().map(|p| p.spec.as_str()).collect();
        assert_eq!(order, ["d", "a", "c", "b", "e"]);
        // Stability under shuffles: reversing the input changes nothing.
        let mut rev = vec![
            mk("e", f64::NAN),
            mk("d", 5.0),
            mk("a", 2.0),
            mk("b", f64::NAN),
            mk("c", 2.0),
        ];
        sort_fleet_points(&mut rev);
        assert_eq!(rev.iter().map(|p| p.spec.as_str()).collect::<Vec<_>>(), order);
    }
}
