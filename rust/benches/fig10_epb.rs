//! Figure 10 reproduction: "EPB comparison across different diffusion
//! models" — energy-per-bit of DiffLight vs the six platforms.
//!
//! Prints the per-model EPB series and the average ratios the paper
//! quotes: 32.9×, 94.18×, 376×, 67×, 3×, 4.51× lower EPB.

#[path = "harness.rs"]
mod harness;

use difflight::arch::cost::OptFlags;
use difflight::baselines::all_baselines;
use difflight::sim::Simulator;
use difflight::util::stats;
use difflight::util::table::fmt_si;
use difflight::workload::{ModelId, ModelSpec};

const PAPER_RATIOS: [(&str, f64); 6] = [
    ("CPU", 32.9),
    ("GPU", 94.18),
    ("DeepCache", 376.0),
    ("FPGA_Acc1", 67.0),
    ("FPGA_Acc2", 3.0),
    ("PACE", 4.51),
];

fn main() {
    harness::section("Figure 10: EPB per model per platform (J/bit)");
    let sim = Simulator::paper_optimal();
    let baselines = all_baselines();

    print!("{:<18} {:>14}", "model", "DiffLight");
    for b in &baselines {
        print!(" {:>14}", b.name());
    }
    println!();

    let mut dl = Vec::new();
    let mut platform_epb: Vec<Vec<f64>> = vec![Vec::new(); baselines.len()];
    for id in ModelId::ALL {
        let spec = ModelSpec::get(id);
        let run = sim.run_model(&spec, OptFlags::ALL);
        dl.push(run.epb());
        print!("{:<18} {:>14}", spec.id.name(), fmt_si(run.epb(), "J"));
        for (bi, b) in baselines.iter().enumerate() {
            let r = b.run(&spec);
            platform_epb[bi].push(r.epb_j_per_bit);
            print!(" {:>14}", fmt_si(r.epb_j_per_bit, "J"));
        }
        println!();
    }

    harness::section("average EPB ratios, platform / DiffLight (ours vs paper)");
    for (bi, (name, paper)) in PAPER_RATIOS.iter().enumerate() {
        let ratios: Vec<f64> = dl
            .iter()
            .zip(&platform_epb[bi])
            .map(|(d, p)| p / d)
            .collect();
        let ours = stats::mean(&ratios);
        println!("{name:<10} ours {ours:8.2}x   paper {paper:>7.2}x");
        assert!(
            (ours / paper - 1.0).abs() < 0.25,
            "{name}: ratio {ours:.2} vs paper {paper}"
        );
    }
    println!("\npaper: \"at least 3x lower EPB ... compared to state-of-the-art\"");

    harness::section("timing");
    let spec = ModelSpec::get(ModelId::LdmChurches);
    harness::bench("run_model(LDM1, ALL)", 30, || {
        harness::black_box(sim.run_model(&spec, OptFlags::ALL));
    });
}
