//! Step-level continuous-batching scheduler over a device fleet.
//!
//! Replaces the coordinator's run-to-completion denoise loop: every
//! device owns a resident step batch plus an admission queue, and
//! requests join/leave the batch **between UNet calls**. The event loop
//! advances simulated time from event to event (request arrivals and
//! device step completions); at each step boundary finished samples
//! leave, queued requests are promoted into the freed slots, and the
//! next fused step starts. A late-arriving request therefore begins
//! denoising as soon as the in-flight step completes — it never waits
//! for the whole earlier batch to finish its generation.
//!
//! Per-row sampler updates inside a fused step are independent, so they
//! fan out over [`crate::util::threadpool::ThreadPool`]; each row owns
//! its ancestral RNG stream, keeping results bit-identical regardless of
//! worker interleaving.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::coordinator::request::{RequestId, SamplerKind};
use crate::coordinator::sampler::{initial_noise, DdimSampler, DdpmSampler, Sampler};
use crate::runtime::manifest::NoiseSchedule;
use crate::util::rng::XorShift;
use crate::util::threadpool::ThreadPool;

use super::device::{Device, DeviceId, ReuseSchedule};
use super::metrics::{DeviceMetrics, FleetMetrics};
use super::router::{DeviceLoad, Router};
use super::ClusterConfig;

/// A generation request with a simulated arrival time.
#[derive(Debug, Clone)]
pub struct ClusterRequest {
    pub id: RequestId,
    pub seed: u64,
    pub sampler: SamplerKind,
    /// Simulated arrival time, seconds.
    pub arrival_s: f64,
}

impl ClusterRequest {
    pub fn new(id: u64, seed: u64, sampler: SamplerKind, arrival_s: f64) -> Self {
        Self { id: RequestId(id), seed, sampler, arrival_s }
    }
}

/// A finished generation with its fleet timeline.
#[derive(Debug, Clone)]
pub struct ClusterResult {
    pub id: RequestId,
    pub device: DeviceId,
    pub sample: Vec<f32>,
    pub steps: usize,
    pub arrival_s: f64,
    /// Simulated time the first denoise step began.
    pub first_step_s: f64,
    pub finish_s: f64,
    /// Mean fused-batch size this sample actually ran at.
    pub mean_batch: f64,
    /// Denoise steps that ran the full UNet (the rest were DeepCache
    /// shallow cache-hit steps; equals `steps` when reuse is off).
    pub full_steps: usize,
}

impl ClusterResult {
    pub fn latency_s(&self) -> f64 {
        self.finish_s - self.arrival_s
    }

    pub fn queue_s(&self) -> f64 {
        self.first_step_s - self.arrival_s
    }
}

/// Outcome of serving one workload through the fleet.
#[derive(Debug, Clone)]
pub struct ClusterOutcome {
    pub results: Vec<ClusterResult>,
    /// Requests shed by admission control (every device full).
    pub rejected: Vec<RequestId>,
    pub metrics: FleetMetrics,
}

/// Concrete sampler per slot, behind `Arc` so the per-row clones handed
/// to the thread pool share one schedule instead of deep-copying the
/// α/β tables on every fused step.
#[derive(Debug, Clone)]
enum SlotSampler {
    Ddpm(Arc<DdpmSampler>),
    Ddim(Arc<DdimSampler>),
}

impl SlotSampler {
    fn build(kind: SamplerKind, schedule: &NoiseSchedule) -> Self {
        match kind {
            SamplerKind::Ddpm => SlotSampler::Ddpm(Arc::new(DdpmSampler::new(schedule.clone()))),
            SamplerKind::Ddim { steps } => {
                SlotSampler::Ddim(Arc::new(DdimSampler::new(schedule.clone(), steps)))
            }
        }
    }

    fn timesteps(&self) -> Vec<usize> {
        match self {
            SlotSampler::Ddpm(s) => s.timesteps(),
            SlotSampler::Ddim(s) => s.timesteps(),
        }
    }

    fn apply(&self, step_index: usize, x: &mut [f32], eps: &[f32], rng: &mut XorShift) {
        match self {
            SlotSampler::Ddpm(s) => s.step(step_index, x, eps, rng),
            SlotSampler::Ddim(s) => s.step(step_index, x, eps, rng),
        }
    }
}

/// One sample resident on (or queued for) a device.
#[derive(Debug, Clone)]
struct Slot {
    req: ClusterRequest,
    sampler: SlotSampler,
    timesteps: Vec<usize>,
    step_index: usize,
    x: Vec<f32>,
    rng: XorShift,
    first_step_s: Option<f64>,
    /// Sum of fused-batch sizes over this sample's executed steps
    /// (actual occupancy, for reporting).
    occupancy_sum: u64,
    /// Steps that ran the full UNet (vs DeepCache shallow steps).
    full_steps: u64,
}

/// The compute behind one fused denoise step. The cluster separates
/// *timing* (device cost model) from *compute* (this trait): the
/// coordinator plugs in its PJRT runtime, while pure-simulation callers
/// (tests, benches, the `cluster` CLI subcommand) use [`SimExecutor`].
pub trait StepExecutor {
    /// ε̂ = UNet(x, t) for a fused batch: `x` is `k·elems` row-major,
    /// `t` holds one timestep per row. Returns `k·elems` predicted noise.
    fn predict_noise(
        &mut self,
        device: DeviceId,
        x: &[f32],
        t: &[f32],
        elems: usize,
    ) -> crate::Result<Vec<f32>>;
}

/// Closed-form stand-in for the UNet: a smooth, timestep-modulated local
/// mix, deterministic in (x, t).
///
/// The offline PJRT stub (`vendor/xla`) uses the same formula, but the
/// two are deliberately independent copies: this crate must not depend
/// on the stub's internals (the vendor path gets swapped for real
/// bindings), and nothing anywhere compares SimExecutor samples against
/// PJRT samples — cross-executor throughput comparisons rest only on
/// the device cost model, which is executor-independent.
pub struct SimExecutor;

impl StepExecutor for SimExecutor {
    fn predict_noise(
        &mut self,
        _device: DeviceId,
        x: &[f32],
        t: &[f32],
        elems: usize,
    ) -> crate::Result<Vec<f32>> {
        anyhow::ensure!(elems > 0 && x.len() == t.len() * elems, "bad fused batch shape");
        let mut eps = Vec::with_capacity(x.len());
        for (row, &tv) in x.chunks_exact(elems).zip(t) {
            let g = 0.85 + 0.15 * (tv as f64 * 0.013).sin();
            let b = 0.05 * (tv as f64 * 0.031).cos();
            for i in 0..elems {
                let prev = row[if i == 0 { elems - 1 } else { i - 1 }] as f64;
                let next = row[if i + 1 == elems { 0 } else { i + 1 }] as f64;
                let mix = 0.8 * row[i] as f64 + 0.1 * prev + 0.1 * next;
                eps.push(((mix * g).tanh() + b) as f32);
            }
        }
        Ok(eps)
    }
}

/// The fleet scheduler: devices + router + event loop state.
pub struct StepScheduler {
    devices: Vec<Device>,
    router: Router,
    pool: ThreadPool,
    schedule: NoiseSchedule,
    elems: usize,
    bit_width: u32,
    resident: Vec<Vec<Slot>>,
    queued: Vec<VecDeque<Slot>>,
    /// Fleet-level deferral queue (bounded by `max_backlog`): requests
    /// that found every device full, re-routed at step boundaries.
    backlog: VecDeque<Slot>,
    max_backlog: usize,
    /// One shared sampler per signature seen, so admission clones an
    /// `Arc` instead of deep-copying the T-length schedule tables.
    sampler_cache: Vec<(SamplerKind, SlotSampler)>,
    /// Work stealing: an idle, empty device pulls queued requests from
    /// the most-loaded busy device at step boundaries.
    work_stealing: bool,
}

impl StepScheduler {
    /// Build a fleet of identical devices priced at `step_cost` for one
    /// single-sample denoise step.
    pub fn new(
        config: &ClusterConfig,
        step_cost: crate::arch::cost::Cost,
        schedule: NoiseSchedule,
        elems: usize,
        bit_width: u32,
    ) -> Self {
        assert!(config.devices >= 1, "cluster needs at least one device");
        let reuse = ReuseSchedule::every(
            config.reuse_interval.max(1),
            config.reuse_shallow_frac,
        );
        let devices: Vec<Device> = (0..config.devices)
            .map(|i| {
                Device::new(
                    i,
                    step_cost,
                    config.capacity,
                    config.max_queue,
                    config.batch_marginal,
                    reuse,
                )
            })
            .collect();
        let workers = config.devices.clamp(2, 8);
        Self {
            resident: vec![Vec::new(); devices.len()],
            queued: vec![VecDeque::new(); devices.len()],
            devices,
            router: Router::new(config.policy),
            pool: ThreadPool::new(workers),
            schedule,
            elems,
            bit_width,
            backlog: VecDeque::new(),
            max_backlog: config.max_backlog,
            sampler_cache: Vec::new(),
            work_stealing: config.work_stealing,
        }
    }

    pub fn device_count(&self) -> usize {
        self.devices.len()
    }

    /// Occupancy snapshot for the router.
    fn loads(&self) -> Vec<DeviceLoad> {
        self.devices
            .iter()
            .enumerate()
            .map(|(i, d)| DeviceLoad {
                resident: self.resident[i].len(),
                queued: self.queued[i].len(),
                capacity: d.capacity,
                max_queue: d.max_queue,
            })
            .collect()
    }

    /// Serve a workload to completion. Requests may arrive in any order;
    /// the loop processes them by simulated arrival time.
    pub fn serve(
        &mut self,
        mut requests: Vec<ClusterRequest>,
        executor: &mut dyn StepExecutor,
    ) -> crate::Result<ClusterOutcome> {
        requests.sort_by(|a, b| {
            a.arrival_s.total_cmp(&b.arrival_s).then(a.id.cmp(&b.id))
        });
        let first_arrival_s = requests.first().map_or(0.0, |r| r.arrival_s);
        // Each serve call is one accounting window.
        for d in &mut self.devices {
            d.reset_accounting();
        }
        let mut pending = requests.into_iter().peekable();
        let mut results: Vec<ClusterResult> = Vec::new();
        let mut rejected: Vec<RequestId> = Vec::new();

        loop {
            let next_arrival = pending.peek().map(|r| r.arrival_s);
            let next_completion = self
                .devices
                .iter()
                .filter_map(|d| d.busy_until().map(|t| (t, d.id.0)))
                .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));

            // Arrivals win ties so a request landing exactly on a step
            // boundary is admissible in the very next step.
            let take_arrival = match (next_arrival, next_completion) {
                (None, None) => break,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (Some(at), Some((ct, _))) => at <= ct,
            };
            if take_arrival {
                // Drain the whole same-instant burst before starting any
                // device, so simultaneous requests can share a first step.
                let at = next_arrival.expect("arrival selected");
                while pending.peek().is_some_and(|r| r.arrival_s == at) {
                    let req = pending.next().expect("peeked");
                    self.admit(req, &mut rejected);
                }
                self.kick_idle(at, executor)?;
            } else {
                let (ct, di) = next_completion.expect("completion selected");
                self.complete(di, ct, executor, &mut results)?;
            }
        }

        // Anything still deferred when all devices drained is undeliverable
        // (can only happen with a backlog bound tighter than the fleet).
        rejected.extend(self.backlog.drain(..).map(|s| s.req.id));

        // Makespan spans the active serving window (first arrival → last
        // completion), not absolute simulated time zero.
        let last_finish_s = results.iter().map(|r| r.finish_s).fold(0.0, f64::max);
        let mut metrics = FleetMetrics {
            devices: self.devices.iter().map(DeviceMetrics::snapshot).collect(),
            makespan_s: (last_finish_s - first_arrival_s).max(0.0),
            rejected: rejected.len() as u64,
            bit_width: self.bit_width,
            ..Default::default()
        };
        results.sort_by(|a, b| a.finish_s.total_cmp(&b.finish_s).then(a.id.cmp(&b.id)));
        for r in &results {
            metrics.record_completion(r.latency_s(), r.queue_s());
        }
        Ok(ClusterOutcome { results, rejected, metrics })
    }

    /// Route one arriving request into a device queue, defer it to the
    /// fleet backlog, or shed it.
    fn admit(&mut self, req: ClusterRequest, rejected: &mut Vec<RequestId>) {
        let loads = self.loads();
        match self.router.route(req.sampler, &loads) {
            Some(did) => {
                let slot = self.make_slot(req);
                self.queued[did.0].push_back(slot);
            }
            None if self.backlog.len() < self.max_backlog => {
                let slot = self.make_slot(req);
                self.backlog.push_back(slot);
            }
            None => rejected.push(req.id),
        }
    }

    fn make_slot(&mut self, req: ClusterRequest) -> Slot {
        let sampler = self.sampler_for(req.sampler);
        let timesteps = sampler.timesteps();
        Slot {
            x: initial_noise(req.seed, self.elems),
            rng: XorShift::new(req.seed ^ 0xA5A5_5A5A_DEAD_BEEF),
            sampler,
            timesteps,
            step_index: 0,
            first_step_s: None,
            occupancy_sum: 0,
            full_steps: 0,
            req,
        }
    }

    /// Shared sampler for a signature (built once, then `Arc`-cloned).
    fn sampler_for(&mut self, kind: SamplerKind) -> SlotSampler {
        if let Some((_, s)) = self.sampler_cache.iter().find(|(k, _)| *k == kind) {
            return s.clone();
        }
        let s = SlotSampler::build(kind, &self.schedule);
        self.sampler_cache.push((kind, s.clone()));
        s
    }

    /// Re-route deferred requests once device queues have space (called
    /// at every step boundary, FIFO so deferral preserves arrival order).
    fn drain_backlog(&mut self) {
        while let Some(slot) = self.backlog.front() {
            let loads = self.loads();
            match self.router.route(slot.req.sampler, &loads) {
                Some(did) => {
                    let slot = self.backlog.pop_front().expect("peeked");
                    self.queued[did.0].push_back(slot);
                }
                None => break,
            }
        }
    }

    /// Start a step on every idle device that has work (resident samples
    /// mid-generation or admitted queue entries). A device that went idle
    /// with nothing at all first tries to steal queued work from the
    /// most-loaded busy device.
    fn kick_idle(&mut self, now_s: f64, executor: &mut dyn StepExecutor) -> crate::Result<()> {
        for di in 0..self.devices.len() {
            if !self.devices[di].is_idle() {
                continue;
            }
            if self.work_stealing
                && self.queued[di].is_empty()
                && self.resident[di].is_empty()
            {
                self.steal_into(di);
            }
            if !self.queued[di].is_empty() || !self.resident[di].is_empty() {
                self.start_step(di, now_s, executor)?;
            }
        }
        Ok(())
    }

    /// Work stealing (ROADMAP "Scaling out"): an idle device with an
    /// empty admission queue pulls the oldest queued requests from the
    /// most-loaded device, up to its own batch capacity. Donors must be
    /// mid-step (their queued work is guaranteed to wait at least one
    /// full step; an idle donor starts its own work this same boundary).
    /// Deterministic: ties break toward the lowest donor id.
    fn steal_into(&mut self, di: usize) {
        while self.resident[di].len() + self.queued[di].len() < self.devices[di].capacity {
            let donor = (0..self.devices.len())
                .filter(|&j| j != di && !self.devices[j].is_idle() && !self.queued[j].is_empty())
                .max_by_key(|&j| (self.queued[j].len(), std::cmp::Reverse(j)));
            let Some(j) = donor else { break };
            let slot = self.queued[j].pop_front().expect("donor queue non-empty");
            self.queued[di].push_back(slot);
        }
    }

    /// Handle a device's step-completion event: retire finished samples,
    /// promote queued requests into the freed slots, start the next step.
    fn complete(
        &mut self,
        di: usize,
        now_s: f64,
        executor: &mut dyn StepExecutor,
        results: &mut Vec<ClusterResult>,
    ) -> crate::Result<()> {
        self.devices[di].finish_step();
        let mut still_resident = Vec::with_capacity(self.resident[di].len());
        for slot in self.resident[di].drain(..) {
            if slot.step_index >= slot.timesteps.len() {
                self.devices[di].samples_completed += 1;
                let steps = slot.timesteps.len();
                results.push(ClusterResult {
                    id: slot.req.id,
                    device: DeviceId(di),
                    sample: slot.x,
                    steps,
                    arrival_s: slot.req.arrival_s,
                    first_step_s: slot.first_step_s.unwrap_or(slot.req.arrival_s),
                    finish_s: now_s,
                    mean_batch: slot.occupancy_sum as f64 / steps.max(1) as f64,
                    full_steps: slot.full_steps as usize,
                });
            } else {
                still_resident.push(slot);
            }
        }
        self.resident[di] = still_resident;
        // Freed slots (and queue space) may unblock deferred requests —
        // possibly onto other, currently idle devices, so kick them all.
        self.drain_backlog();
        self.kick_idle(now_s, executor)
    }

    /// Promote queued requests into free slots and launch the next fused
    /// step (no-op when nothing is resident).
    fn start_step(
        &mut self,
        di: usize,
        now_s: f64,
        executor: &mut dyn StepExecutor,
    ) -> crate::Result<()> {
        while self.resident[di].len() < self.devices[di].capacity {
            let Some(mut slot) = self.queued[di].pop_front() else { break };
            slot.first_step_s = Some(now_s);
            self.resident[di].push(slot);
        }
        let k = self.resident[di].len();
        if k == 0 {
            return Ok(());
        }

        // DeepCache step reuse: the device cycles full/shallow steps;
        // admission phase-aligns to the cycle (a freshly promoted sample
        // — `step_index == 0`, empty feature cache — escalates the fused
        // step to full and restarts the cycle, so every resident row
        // always agrees on the step class). In simulation the executor
        // still runs every step — reuse changes the *priced* cost, not
        // the sample trajectory, so `K` is a pure performance knob and
        // results stay bit-identical across reuse intervals.
        let force_full = self.resident[di].iter().any(|s| s.step_index == 0);
        let full = self.devices[di].next_step_full(force_full);

        // Fused UNet call: one t per row (rows may sit at different
        // denoise depths — that is the whole point of step-level batching).
        let elems = self.elems;
        let mut x = Vec::with_capacity(k * elems);
        let mut t = Vec::with_capacity(k);
        for slot in &self.resident[di] {
            x.extend_from_slice(&slot.x);
            t.push(slot.timesteps[slot.step_index] as f32);
        }
        let eps = executor.predict_noise(DeviceId(di), &x, &t, elems)?;
        anyhow::ensure!(eps.len() == k * elems, "executor returned {} elems, want {}", eps.len(), k * elems);

        // Per-row sampler updates are independent; fan out over the pool.
        // Rows (x, rng) are moved out and back rather than cloned; the
        // sampler clone is an `Arc` bump. Each row owns its RNG, so
        // worker order cannot change results.
        let items: Vec<(Vec<f32>, Vec<f32>, SlotSampler, usize, XorShift)> = self.resident[di]
            .iter_mut()
            .enumerate()
            .map(|(i, slot)| {
                (
                    std::mem::take(&mut slot.x),
                    eps[i * elems..(i + 1) * elems].to_vec(),
                    slot.sampler.clone(),
                    slot.step_index,
                    slot.rng.clone(),
                )
            })
            .collect();
        let updated = self.pool.map(items, |(mut x, eps, sampler, idx, mut rng)| {
            sampler.apply(idx, &mut x, &eps, &mut rng);
            (x, rng)
        });
        for (slot, (x, rng)) in self.resident[di].iter_mut().zip(updated) {
            slot.x = x;
            slot.rng = rng;
            slot.step_index += 1;
            slot.occupancy_sum += k as u64;
            slot.full_steps += full as u64;
        }
        self.devices[di].begin_step(now_s, k, full);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::cost::Cost;
    use crate::cluster::router::ShardPolicy;

    fn config(devices: usize) -> ClusterConfig {
        ClusterConfig {
            devices,
            capacity: 4,
            max_queue: 64,
            policy: ShardPolicy::LeastLoaded,
            ..ClusterConfig::default()
        }
    }

    fn scheduler(devices: usize) -> StepScheduler {
        StepScheduler::new(
            &config(devices),
            Cost::new(1e-3, 2e-3, 1_000_000, 4),
            NoiseSchedule::linear(100),
            16,
            8,
        )
    }

    fn workload(n: usize, steps: usize) -> Vec<ClusterRequest> {
        (0..n)
            .map(|i| ClusterRequest::new(i as u64, 100 + i as u64, SamplerKind::Ddim { steps }, 0.0))
            .collect()
    }

    #[test]
    fn serves_everything_exactly_once() {
        let mut s = scheduler(2);
        let out = s.serve(workload(10, 8), &mut SimExecutor).unwrap();
        assert_eq!(out.results.len(), 10);
        assert!(out.rejected.is_empty());
        let mut ids: Vec<u64> = out.results.iter().map(|r| r.id.0).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
        assert_eq!(out.metrics.samples_completed, 10);
        for r in &out.results {
            assert_eq!(r.steps, 8);
            assert!(r.sample.iter().all(|v| v.is_finite()));
            assert!(r.finish_s > r.first_step_s && r.first_step_s >= r.arrival_s);
        }
    }

    #[test]
    fn deterministic_across_runs_and_pool_schedules() {
        let run = || {
            let mut s = scheduler(3);
            s.serve(workload(9, 6), &mut SimExecutor).unwrap()
        };
        let (a, b) = (run(), run());
        for (ra, rb) in a.results.iter().zip(&b.results) {
            assert_eq!(ra.id, rb.id);
            assert_eq!(ra.sample, rb.sample, "fleet serving must be bit-deterministic");
            assert!((ra.finish_s - rb.finish_s).abs() < 1e-12);
        }
    }

    #[test]
    fn sample_matches_single_device_result() {
        // Sharding must not change what a given (seed, sampler) generates.
        let serve = |devices: usize| {
            let mut s = scheduler(devices);
            let mut out = s.serve(workload(8, 5), &mut SimExecutor).unwrap();
            out.results.sort_by_key(|r| r.id);
            out.results.into_iter().map(|r| r.sample).collect::<Vec<_>>()
        };
        assert_eq!(serve(1), serve(4));
    }

    #[test]
    fn late_arrival_interleaves_into_running_batch() {
        // One device, capacity 4: a full batch starts at t=0 on a long
        // generation; a request arriving mid-flight must start stepping
        // before the first batch finishes.
        let mut s = StepScheduler::new(
            &ClusterConfig { devices: 1, capacity: 8, ..ClusterConfig::default() },
            Cost::new(1e-3, 2e-3, 1_000_000, 4),
            NoiseSchedule::linear(100),
            16,
            8,
        );
        let mut reqs = workload(4, 50);
        reqs.push(ClusterRequest::new(99, 7, SamplerKind::Ddim { steps: 50 }, 5e-3));
        let out = s.serve(reqs, &mut SimExecutor).unwrap();
        let early_finish = out
            .results
            .iter()
            .filter(|r| r.id.0 < 4)
            .map(|r| r.finish_s)
            .fold(f64::INFINITY, f64::min);
        let late = out.results.iter().find(|r| r.id.0 == 99).unwrap();
        assert!(
            late.first_step_s < early_finish,
            "late request must start denoising ({}) before the earlier batch finishes ({})",
            late.first_step_s,
            early_finish
        );
        assert!(late.queue_s() < 2e-3, "admission happens at the next step boundary");
    }

    #[test]
    fn admission_control_sheds_overload() {
        let mut s = StepScheduler::new(
            &ClusterConfig {
                devices: 1,
                capacity: 2,
                max_queue: 2,
                ..ClusterConfig::default()
            },
            Cost::new(1e-3, 2e-3, 1_000_000, 4),
            NoiseSchedule::linear(100),
            16,
            8,
        );
        let out = s.serve(workload(10, 4), &mut SimExecutor).unwrap();
        assert_eq!(out.results.len() + out.rejected.len(), 10);
        assert!(
            !out.rejected.is_empty(),
            "10 simultaneous requests cannot fit capacity 2 + queue 2"
        );
        assert_eq!(out.metrics.rejected as usize, out.rejected.len());
    }

    #[test]
    fn backlog_defers_instead_of_shedding() {
        // Tiny fleet, big burst: with a backlog bound, overload waits at
        // the fleet level and is re-routed as step boundaries free slots
        // — nothing is dropped, everything is served exactly once.
        let mut s = StepScheduler::new(
            &ClusterConfig {
                devices: 2,
                capacity: 1,
                max_queue: 0,
                max_backlog: 64,
                ..ClusterConfig::default()
            },
            Cost::new(1e-3, 2e-3, 1_000_000, 4),
            NoiseSchedule::linear(100),
            16,
            8,
        );
        let out = s.serve(workload(9, 3), &mut SimExecutor).unwrap();
        assert!(out.rejected.is_empty(), "backlog must absorb the burst");
        let mut ids: Vec<u64> = out.results.iter().map(|r| r.id.0).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..9).collect::<Vec<_>>());
        // Solo capacity ⇒ every sample ran at occupancy exactly 1.
        assert!(out.results.iter().all(|r| (r.mean_batch - 1.0).abs() < 1e-12));
    }

    #[test]
    fn mean_batch_reflects_actual_occupancy() {
        // 4 simultaneous requests on one capacity-4 device with equal
        // step counts run every step fully fused: occupancy exactly 4.
        let mut s = scheduler(1);
        let out = s.serve(workload(4, 6), &mut SimExecutor).unwrap();
        for r in &out.results {
            assert!((r.mean_batch - 4.0).abs() < 1e-12, "occupancy {}", r.mean_batch);
        }
        // A lone request can never report more than occupancy 1.
        let mut s = scheduler(1);
        let out = s.serve(workload(1, 6), &mut SimExecutor).unwrap();
        assert!((out.results[0].mean_batch - 1.0).abs() < 1e-12);
    }

    fn scheduler_with(config: ClusterConfig) -> StepScheduler {
        StepScheduler::new(
            &config,
            Cost::new(1e-3, 2e-3, 1_000_000, 4),
            NoiseSchedule::linear(100),
            16,
            8,
        )
    }

    #[test]
    fn reuse_interval_one_reproduces_no_reuse_exactly() {
        // K=1 must be the pre-reuse scheduler bit-for-bit: the shallow
        // fraction is never exercised, every step is a full UNet step,
        // and all timings/metrics match the default (no-reuse) config.
        let base = config(2);
        let k1 = ClusterConfig {
            reuse_interval: 1,
            reuse_shallow_frac: 0.125, // must be irrelevant at K=1
            ..config(2)
        };
        let out_a = scheduler_with(base).serve(workload(10, 8), &mut SimExecutor).unwrap();
        let out_b = scheduler_with(k1).serve(workload(10, 8), &mut SimExecutor).unwrap();
        assert_eq!(out_a.results.len(), out_b.results.len());
        for (ra, rb) in out_a.results.iter().zip(&out_b.results) {
            assert_eq!(ra.id, rb.id);
            assert_eq!(ra.sample, rb.sample);
            assert_eq!(ra.finish_s, rb.finish_s, "K=1 timing must be bit-identical");
            assert_eq!(ra.full_steps, ra.steps, "no shallow steps at K=1");
        }
        assert_eq!(out_b.metrics.reuse_hits(), 0);
        assert_eq!(out_b.metrics.reuse_misses(), 10 * 8);
        assert_eq!(out_a.metrics.makespan_s, out_b.metrics.makespan_s);
    }

    #[test]
    fn reuse_speeds_up_fleet_and_counts_hits() {
        let serve = |k: usize| {
            let cfg = ClusterConfig { reuse_interval: k, ..config(2) };
            scheduler_with(cfg).serve(workload(16, 12), &mut SimExecutor).unwrap()
        };
        let (k1, k3) = (serve(1), serve(3));
        // Reuse is a pure cost-model knob: samples stay bit-identical.
        for (ra, rb) in k1.results.iter().zip(&k3.results) {
            assert_eq!(ra.id, rb.id);
            assert_eq!(ra.sample, rb.sample, "reuse must not change samples");
        }
        let t1 = k1.metrics.throughput_samples_per_s();
        let t3 = k3.metrics.throughput_samples_per_s();
        assert!(
            t3 >= 1.5 * t1,
            "K=3 reuse must lift simulated throughput >= 1.5x (got {:.2}x)",
            t3 / t1
        );
        assert_eq!(k1.metrics.reuse_hits(), 0);
        assert!(k3.metrics.reuse_hits() > 0, "K=3 must record cache hits");
        let total: u64 = k3.metrics.reuse_hits() + k3.metrics.reuse_misses();
        let steps: u64 = k3.metrics.devices.iter().map(|d| d.steps_executed).sum();
        assert_eq!(total, steps, "every sample-step is either a hit or a miss");
        for r in &k3.results {
            assert!(r.full_steps >= 1, "first step always runs the full UNet");
            assert!(r.full_steps < r.steps, "some steps must be shallow at K=3");
        }
    }

    #[test]
    fn work_stealing_balances_skewed_queues() {
        // Least-loaded routing alternates the t=0 burst: even ids (long,
        // 40-step generations) land on device 0, odd ids (2-step) on
        // device 1. Device 1 drains quickly and must then steal device
        // 0's queued work instead of idling.
        let cfg = |stealing: bool| ClusterConfig {
            devices: 2,
            capacity: 1,
            max_queue: 16,
            policy: ShardPolicy::LeastLoaded,
            work_stealing: stealing,
            ..ClusterConfig::default()
        };
        let reqs = || -> Vec<ClusterRequest> {
            (0..8)
                .map(|i| {
                    let steps = if i % 2 == 0 { 40 } else { 2 };
                    ClusterRequest::new(i, 100 + i, SamplerKind::Ddim { steps }, 0.0)
                })
                .collect()
        };
        let with = scheduler_with(cfg(true)).serve(reqs(), &mut SimExecutor).unwrap();
        let without = scheduler_with(cfg(false)).serve(reqs(), &mut SimExecutor).unwrap();
        assert_eq!(with.results.len(), 8);
        assert_eq!(without.results.len(), 8);
        // Without stealing, device 0 serializes all four long jobs.
        assert!(
            with.metrics.makespan_s < 0.7 * without.metrics.makespan_s,
            "stealing must shorten the makespan ({} vs {})",
            with.metrics.makespan_s,
            without.metrics.makespan_s
        );
        let stolen = with
            .results
            .iter()
            .any(|r| r.id.0 % 2 == 0 && r.device == DeviceId(1));
        assert!(stolen, "device 1 must have stolen at least one long job");
        // Stealing never changes what gets generated.
        for ra in &with.results {
            let rb = without.results.iter().find(|r| r.id == ra.id).unwrap();
            assert_eq!(ra.sample, rb.sample);
        }
    }

    #[test]
    fn executor_error_propagates() {
        struct Broken;
        impl StepExecutor for Broken {
            fn predict_noise(
                &mut self,
                _d: DeviceId,
                _x: &[f32],
                _t: &[f32],
                _e: usize,
            ) -> crate::Result<Vec<f32>> {
                anyhow::bail!("device fault injected")
            }
        }
        let mut s = scheduler(2);
        assert!(s.serve(workload(4, 4), &mut Broken).is_err());
    }
}
