//! The L3 serving coordinator.
//!
//! Rust owns the event loop, request admission, dynamic batching, the
//! denoising timestep schedule, and all state; each timestep's compute is
//! one PJRT call into the AOT UNet (`crate::runtime`). This is the
//! serving-side counterpart of the DiffLight accelerator: the ECU's
//! roles — buffering intermediate results, mapping work onto compute,
//! sequencing softmax/timesteps — live here at the system level.
//!
//! * [`request`] — generation requests/results and ids.
//! * [`batcher`] — dynamic batcher: admission queue → batches under a
//!   max-size/max-wait policy.
//! * [`sampler`] — DDPM/DDIM ancestral samplers over the AOT schedule.
//! * [`engine`] — the serving loop tying them together, with metrics.
//!   When `EngineConfig::cluster` names more than one device (or any
//!   profile runs DeepCache reuse) the engine hands the queue to the
//!   [`crate::cluster`] step-level fleet scheduler instead of the
//!   single-device run-to-completion loop.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod sampler;

pub use batcher::{BatchPolicy, DynamicBatcher};
pub use engine::{Coordinator, EngineConfig};
pub use request::{GenerationRequest, GenerationResult, RequestId};
pub use sampler::{DdimSampler, DdpmSampler, Sampler};
