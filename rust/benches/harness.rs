//! Shared bench harness (criterion is not in the vendored crate set).
//!
//! Provides warmup + repeated timing with mean/stddev/min reporting, so
//! every paper-figure bench both *regenerates the figure's data* and
//! *times the code that produces it*.

#![allow(dead_code)]

use std::time::Instant;

/// Timing summary of one benchmark case.
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:40} {:4} iters  mean {:>12}  min {:>12}  (+/- {:.1}%)",
            self.name,
            self.iters,
            fmt_t(self.mean_s),
            fmt_t(self.min_s),
            if self.mean_s > 0.0 { 100.0 * self.stddev_s / self.mean_s } else { 0.0 },
        );
    }
}

fn fmt_t(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Time `f` with warmup; returns the summary (and prints it).
pub fn bench(name: &str, iters: usize, mut f: impl FnMut()) -> BenchResult {
    // Warmup: 1/4 of iters, at least one.
    for _ in 0..(iters / 4).max(1) {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / samples.len().max(2) as f64;
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        stddev_s: var.sqrt(),
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
    };
    result.report();
    result
}

/// A guard against the optimizer deleting benched work.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Section banner.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

// ---------------------------------------------------------------------
// Fleet-scale scheduler-scaling workload, shared by `cluster_scale` and
// `sim_hot_path` so both sweeps measure the same points: small samples
// (8 elems) and short DDIM generations make host-side event processing
// — not executor compute — dominate, so scheduler overhead is what gets
// measured.
// ---------------------------------------------------------------------

pub const FLEET_SCALE_ELEMS: usize = 8;
pub const FLEET_SCALE_STEPS: usize = 12;
pub const FLEET_SCALE_REQS_PER_DEVICE: usize = 32;

/// Time one scheduler core (heap event core, or the retained O(N)
/// reference loop) on the scaling workload at a fleet size; returns
/// `(events, min host seconds, events/sec at the min)`. Min-of-N rather
/// than the mean: this ratio gates CI (`scripts/verify.sh` smoke-runs
/// the 64-device point), so it must shrug off transient host load.
pub fn fleet_scale_time_core(devices: usize, iters: usize, reference: bool) -> (u64, f64, f64) {
    fleet_scale_time_core_traced(devices, iters, reference, false)
}

/// [`fleet_scale_time_core`] with an optional flight-recorder sink
/// attached for every serve — the `traced=true` arm is what the `obs`
/// bench section compares against `traced=false` to gate recorder
/// overhead (events are buffered as `Copy` structs during the loop;
/// JSON formatting happens outside the timed window).
pub fn fleet_scale_time_core_traced(
    devices: usize,
    iters: usize,
    reference: bool,
    traced: bool,
) -> (u64, f64, f64) {
    use difflight::arch::cost::Cost;
    use difflight::cluster::{
        synthetic_workload, ClusterConfig, ReferenceScheduler, ShardPolicy, SimExecutor,
        StepScheduler, TraceSink,
    };
    use difflight::coordinator::request::SamplerKind;
    use difflight::runtime::manifest::NoiseSchedule;

    let cfg = ClusterConfig::with_devices(devices)
        .capacity(4)
        .max_queue(16)
        .backlog(usize::MAX)
        .policy(ShardPolicy::LeastLoaded);
    let costs = vec![Cost::new(1e-3, 2e-3, 1_000_000, 4); cfg.fleet.len()];
    let schedule = NoiseSchedule::linear(100);
    let workload = synthetic_workload(
        devices * FLEET_SCALE_REQS_PER_DEVICE,
        13,
        SamplerKind::Ddim { steps: FLEET_SCALE_STEPS },
        1e-5,
    );
    let mut events = 0u64;
    let name = format!(
        "{}({devices} dev).serve({} reqs){}",
        if reference { "reference" } else { "heap" },
        workload.len(),
        if traced { " traced" } else { "" }
    );
    let timing = if reference {
        let mut s = ReferenceScheduler::new(&cfg, &costs, schedule, FLEET_SCALE_ELEMS);
        if traced {
            s.set_trace(TraceSink::new());
        }
        bench(&name, iters, || {
            let out = s.serve(workload.clone(), &mut SimExecutor).expect("serve");
            events = out.metrics.sched_events;
            black_box(out);
        })
    } else {
        let mut s = StepScheduler::new(&cfg, &costs, schedule, FLEET_SCALE_ELEMS);
        if traced {
            s.set_trace(TraceSink::new());
        }
        bench(&name, iters, || {
            let out = s.serve(workload.clone(), &mut SimExecutor).expect("serve");
            events = out.metrics.sched_events;
            black_box(out);
        })
    };
    (events, timing.min_s, events as f64 / timing.min_s)
}

/// Time the frozen pre-shard baseline (`LegacyStepScheduler`) on the
/// same scaling workload — the denominator of the arena/4-ary layout
/// gate, so "faster" is measured against the real predecessor core.
pub fn fleet_scale_time_legacy(devices: usize, iters: usize) -> (u64, f64, f64) {
    use difflight::arch::cost::Cost;
    use difflight::cluster::{
        synthetic_workload, ClusterConfig, LegacyStepScheduler, ShardPolicy, SimExecutor,
    };
    use difflight::coordinator::request::SamplerKind;
    use difflight::runtime::manifest::NoiseSchedule;

    let cfg = ClusterConfig::with_devices(devices)
        .capacity(4)
        .max_queue(16)
        .backlog(usize::MAX)
        .policy(ShardPolicy::LeastLoaded);
    let costs = vec![Cost::new(1e-3, 2e-3, 1_000_000, 4); cfg.fleet.len()];
    let workload = synthetic_workload(
        devices * FLEET_SCALE_REQS_PER_DEVICE,
        13,
        SamplerKind::Ddim { steps: FLEET_SCALE_STEPS },
        1e-5,
    );
    let mut s =
        LegacyStepScheduler::new(&cfg, &costs, NoiseSchedule::linear(100), FLEET_SCALE_ELEMS);
    let mut events = 0u64;
    let timing = bench(&format!("legacy({devices} dev).serve({} reqs)", workload.len()), iters, || {
        let out = s.serve(workload.clone(), &mut SimExecutor).expect("serve");
        events = out.metrics.sched_events;
        black_box(out);
    });
    (events, timing.min_s, events as f64 / timing.min_s)
}

// ---------------------------------------------------------------------
// Shard-sweep workload: unlike the fleet-scale workload above (tiny
// samples, scheduler-dominated), this one makes the *numeric step work*
// dominate — large samples, a gap-0 burst so every device steps in
// lockstep epochs — which is exactly what the sharded event core fans
// out across workers at the deferred-flush boundary. Events/sec here
// measures end-to-end serve speed on a compute-heavy drain, so the
// shards ∈ {1, 4, 8} sweep exposes the parallel speedup while staying
// bit-identical across shard counts.
// ---------------------------------------------------------------------

pub const SHARD_SWEEP_ELEMS: usize = 1024;
pub const SHARD_SWEEP_STEPS: usize = 6;
pub const SHARD_SWEEP_REQS_PER_DEVICE: usize = 2;

/// Time the sharded core at a `(devices, shards)` point on the
/// compute-dominated shard-sweep workload; returns `(events, min host
/// seconds, events/sec at the min)`. Min-of-N for the same reason as
/// [`fleet_scale_time_core`]: the ratios gate CI.
pub fn shard_sweep_time(devices: usize, shards: usize, iters: usize) -> (u64, f64, f64) {
    use difflight::arch::cost::Cost;
    use difflight::cluster::{
        synthetic_workload, ClusterConfig, ShardPolicy, SimExecutor, StepScheduler,
    };
    use difflight::coordinator::request::SamplerKind;
    use difflight::runtime::manifest::NoiseSchedule;

    let cfg = ClusterConfig::with_devices(devices)
        .capacity(4)
        .max_queue(16)
        .backlog(usize::MAX)
        .policy(ShardPolicy::LeastLoaded)
        .with_shards(shards);
    let costs = vec![Cost::new(1e-3, 2e-3, 1_000_000, 4); cfg.fleet.len()];
    let workload = synthetic_workload(
        devices * SHARD_SWEEP_REQS_PER_DEVICE,
        13,
        SamplerKind::Ddim { steps: SHARD_SWEEP_STEPS },
        0.0,
    );
    let mut s = StepScheduler::new(&cfg, &costs, NoiseSchedule::linear(100), SHARD_SWEEP_ELEMS);
    let mut events = 0u64;
    let name = format!("sharded({devices} dev, {shards} shard).serve({} reqs)", workload.len());
    let timing = bench(&name, iters, || {
        let out = s.serve(workload.clone(), &mut SimExecutor).expect("serve");
        events = out.metrics.sched_events;
        black_box(out);
    });
    (events, timing.min_s, events as f64 / timing.min_s)
}

/// One untimed heap-core serve of the fleet-scale workload, returning
/// the outcome — the `obs` bench section checks the streamed histogram
/// quantiles against the exact per-request latency vector on it.
pub fn fleet_scale_outcome(devices: usize) -> difflight::cluster::ClusterOutcome {
    use difflight::arch::cost::Cost;
    use difflight::cluster::{
        synthetic_workload, ClusterConfig, ShardPolicy, SimExecutor, StepScheduler,
    };
    use difflight::coordinator::request::SamplerKind;
    use difflight::runtime::manifest::NoiseSchedule;

    let cfg = ClusterConfig::with_devices(devices)
        .capacity(4)
        .max_queue(16)
        .backlog(usize::MAX)
        .policy(ShardPolicy::LeastLoaded);
    let costs = vec![Cost::new(1e-3, 2e-3, 1_000_000, 4); cfg.fleet.len()];
    let workload = synthetic_workload(
        devices * FLEET_SCALE_REQS_PER_DEVICE,
        13,
        SamplerKind::Ddim { steps: FLEET_SCALE_STEPS },
        1e-5,
    );
    let mut s = StepScheduler::new(&cfg, &costs, NoiseSchedule::linear(100), FLEET_SCALE_ELEMS);
    s.serve(workload, &mut SimExecutor).expect("serve")
}

// ---------------------------------------------------------------------
// Heterogeneous-fleet workload shared by `cluster_scale` and
// `sim_hot_path`: a mixed big/small DiffLight fleet from the paper's
// DSE family, drained with cost-aware vs occupancy-only routing. Work
// stealing is off in both arms so the comparison isolates the routing
// policy (stealing would partially rescue the occupancy-only arm at
// the tail).
// ---------------------------------------------------------------------

/// The big die: paper-optimal scaled up (more Residual blocks and
/// attention heads), still within the 36-branch design rule.
pub const HETERO_BIG_ARCH: [usize; 6] = [8, 12, 3, 8, 6, 3];
/// The small die: a minimal member of the DSE family (single Residual
/// block, two attention heads).
pub const HETERO_SMALL_ARCH: [usize; 6] = [1, 12, 3, 2, 6, 3];
pub const HETERO_BIG_COUNT: usize = 2;
pub const HETERO_SMALL_COUNT: usize = 6;

/// The mixed 2-big + 6-small fleet spec.
pub fn hetero_fleet() -> Vec<(difflight::cluster::DeviceProfile, usize)> {
    use difflight::arch::ArchConfig;
    use difflight::cluster::DeviceProfile;
    let big = DeviceProfile {
        arch: ArchConfig::from_vector(HETERO_BIG_ARCH, 36),
        ..DeviceProfile::default()
    };
    let small = DeviceProfile {
        arch: ArchConfig::from_vector(HETERO_SMALL_ARCH, 36),
        ..DeviceProfile::default()
    };
    vec![(big, HETERO_BIG_COUNT), (small, HETERO_SMALL_COUNT)]
}

/// Drain `requests` DDIM generations through a fleet config; returns
/// the outcome plus host seconds. Offline semantics (unbounded backlog,
/// nothing shed).
pub fn hetero_drain(
    config: difflight::cluster::ClusterConfig,
    requests: usize,
    steps: usize,
) -> (difflight::cluster::ClusterOutcome, f64) {
    use difflight::cluster::{synthetic_workload, Cluster, SimExecutor};
    use difflight::coordinator::request::SamplerKind;
    use std::time::Instant;

    let mut cluster = Cluster::simulated(config.backlog(usize::MAX).max_queue(256))
        .expect("hetero fleet must satisfy the design rules");
    let workload = synthetic_workload(requests, 17, SamplerKind::Ddim { steps }, 0.0);
    let t0 = Instant::now();
    let out = cluster.serve(workload, &mut SimExecutor).expect("fleet drain");
    let host_s = t0.elapsed().as_secs_f64();
    assert_eq!(out.results.len(), requests, "offline drain must serve everything");
    (out, host_s)
}

// ---------------------------------------------------------------------
// SLO-knee workload, shared by `sim_hot_path` and `cluster_scale`: the
// paper fleet (4 paper-optimal dies) under open-loop Poisson load where
// every request carries a latency deadline. Sweeping the arrival rate
// traces SLO attainment from ~1 down through the knee; at overload,
// deadline-aware shedding (shed doomed work at admission) is compared
// against shed-on-full admission on goodput. All results are simulated
// time, deterministic under host load — safe to gate in CI smoke runs.
// ---------------------------------------------------------------------

pub const SLO_DEVICES: usize = 4;
pub const SLO_CAPACITY: usize = 4;
pub const SLO_MAX_QUEUE: usize = 32;
pub const SLO_STEPS: usize = 8;

/// `(fleet service rate in samples/s, SLO in seconds)` for the knee
/// workload. The rate is the paper fleet's fully-fused throughput
/// ceiling — `devices × capacity` samples per fused generation — and
/// the SLO allows three fused generations of end-to-end latency (own
/// service plus modest queueing).
pub fn slo_workload_params() -> (f64, f64) {
    use difflight::cluster::{profile_step_costs, ClusterConfig, DeviceProfile};

    let cfg = ClusterConfig::with_devices(SLO_DEVICES).capacity(SLO_CAPACITY);
    let step_s = profile_step_costs(&cfg).expect("paper fleet prices")[0].latency_s;
    let marginal = DeviceProfile::default().batch_marginal;
    let fused_gen_s =
        SLO_STEPS as f64 * step_s * (1.0 + marginal * (SLO_CAPACITY - 1) as f64);
    let fleet_rate = (SLO_DEVICES * SLO_CAPACITY) as f64 / fused_gen_s;
    (fleet_rate, 3.0 * fused_gen_s)
}

/// Serve `requests` Poisson arrivals at `rate_rps`, every request
/// carrying `slo_s`, through the paper fleet — with deadline-aware
/// admission (`shed_late`) or plain shed-on-full.
pub fn slo_drain(
    rate_rps: f64,
    requests: usize,
    slo_s: f64,
    shed_late: bool,
) -> difflight::cluster::ClusterOutcome {
    use difflight::cluster::{
        Cluster, ClusterConfig, RequestSource, ShardPolicy, SimExecutor,
    };
    use difflight::coordinator::request::SamplerKind;

    let cfg = ClusterConfig::with_devices(SLO_DEVICES)
        .capacity(SLO_CAPACITY)
        .max_queue(SLO_MAX_QUEUE)
        .policy(ShardPolicy::LeastLoaded)
        .shed_late(shed_late);
    let mut cluster = Cluster::simulated(cfg).expect("paper fleet");
    let source =
        RequestSource::poisson(requests, 29, SamplerKind::Ddim { steps: SLO_STEPS }, rate_rps)
            .with_slos(vec![slo_s]);
    cluster.serve_source(source, &mut SimExecutor).expect("slo drain")
}

// ---------------------------------------------------------------------
// Resilience workload, shared with the `resilience` section of
// `sim_hot_path`: the fleet-scale synthetic workload drained under a
// fault plan. Offline semantics (unbounded backlog, no deadlines), so
// any request loss is fault loss, never admission shedding — which is
// what makes "zero lost with migration" a structural gate rather than a
// tuning-dependent one.
// ---------------------------------------------------------------------

/// Drain the fleet-scale workload through `devices` dies under `plan`,
/// with step-boundary checkpoint/migrate recovery on or off.
pub fn churn_drain(
    devices: usize,
    plan: difflight::cluster::FaultPlan,
    migration: bool,
) -> difflight::cluster::ClusterOutcome {
    use difflight::arch::cost::Cost;
    use difflight::cluster::{
        synthetic_workload, ClusterConfig, ShardPolicy, SimExecutor, StepScheduler,
    };
    use difflight::coordinator::request::SamplerKind;
    use difflight::runtime::manifest::NoiseSchedule;

    let cfg = ClusterConfig::with_devices(devices)
        .capacity(4)
        .max_queue(16)
        .backlog(usize::MAX)
        .policy(ShardPolicy::LeastLoaded)
        .faults(plan)
        .migration(migration);
    let costs = vec![Cost::new(1e-3, 2e-3, 1_000_000, 4); cfg.fleet.len()];
    let workload = synthetic_workload(
        devices * FLEET_SCALE_REQS_PER_DEVICE,
        13,
        SamplerKind::Ddim { steps: FLEET_SCALE_STEPS },
        1e-5,
    );
    let mut s = StepScheduler::new(&cfg, &costs, NoiseSchedule::linear(100), FLEET_SCALE_ELEMS);
    s.serve(workload, &mut SimExecutor).expect("churn drain")
}
