//! Fixed-size thread pool with a scoped parallel-map helper.
//!
//! `tokio`/`rayon` are not in the vendored crate set; the coordinator and
//! the DSE sweep need parallelism, so this provides a small, dependency-free
//! work-stealing-free pool: one shared injector queue guarded by a mutex +
//! condvar. Work items in this codebase are coarse (whole simulator runs,
//! whole denoise batches), so contention on the single queue is negligible.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<std::collections::VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
}

/// A fixed pool of worker threads executing boxed jobs FIFO.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (`n >= 1`).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "thread pool needs at least one worker");
        let shared = Arc::new(Shared {
            queue: Mutex::new(std::collections::VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..n)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("difflight-worker-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, workers }
    }

    /// Machine-sized worker count (at least 2, at most 16). Callers that
    /// need a pool matched to the host — rather than to some workload
    /// dimension like a device count — should size with this.
    pub fn default_workers() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .clamp(2, 16)
    }

    /// Pool sized to the machine (at least 2, at most 16).
    pub fn default_size() -> Self {
        Self::new(Self::default_workers())
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(Box::new(job));
        drop(q);
        self.shared.available.notify_one();
    }

    /// Parallel map: applies `f` to every item, preserving order.
    ///
    /// Blocks until all results are ready. `f` is cloned per item; results
    /// are collected through a shared slot vector, so no channels needed.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + Clone + 'static,
    {
        let n = items.len();
        let slots: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let done = Arc::new((Mutex::new(0usize), Condvar::new()));
        for (i, item) in items.into_iter().enumerate() {
            let slots = Arc::clone(&slots);
            let done = Arc::clone(&done);
            let f = f.clone();
            self.execute(move || {
                let r = f(item);
                slots.lock().unwrap()[i] = Some(r);
                let (lock, cv) = &*done;
                *lock.lock().unwrap() += 1;
                cv.notify_all();
            });
        }
        let (lock, cv) = &*done;
        let mut completed = lock.lock().unwrap();
        while *completed < n {
            completed = cv.wait(completed).unwrap();
        }
        drop(completed);
        // Take the results out of the shared slots (workers may still hold
        // their Arc clones briefly after the final notify).
        let taken: Vec<Option<R>> = std::mem::take(&mut *slots.lock().unwrap());
        taken
            .into_iter()
            .map(|o| o.expect("worker produced result"))
            .collect()
    }

    /// Parallel indexed map with chunked dispatch: applies `f(i, item)`
    /// to every item (where `i` is the item's index in `items`), but
    /// submits one pooled job per `chunk_size`-item chunk instead of one
    /// boxed job per item. Order is preserved. When everything fits in a
    /// single chunk the map runs inline on the caller thread — small
    /// batches pay zero queue/wakeup overhead.
    pub fn map_chunked<T, R, F>(&self, items: Vec<T>, chunk_size: usize, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(usize, T) -> R + Send + Sync + Clone + 'static,
    {
        let chunk_size = chunk_size.max(1);
        if items.len() <= chunk_size {
            return items.into_iter().enumerate().map(|(i, it)| f(i, it)).collect();
        }
        let mut chunks: Vec<(usize, Vec<T>)> = Vec::new();
        let mut start = 0;
        let mut it = items.into_iter();
        loop {
            let chunk: Vec<T> = it.by_ref().take(chunk_size).collect();
            if chunk.is_empty() {
                break;
            }
            let len = chunk.len();
            chunks.push((start, chunk));
            start += len;
        }
        let per_chunk: Vec<Vec<R>> = self.map(chunks, move |(start, chunk)| {
            chunk
                .into_iter()
                .enumerate()
                .map(|(j, item)| f(start + j, item))
                .collect()
        });
        per_chunk.into_iter().flatten().collect()
    }
}

/// Run `f` over every item on its own *scoped* thread and collect the
/// results in item order. Unlike [`ThreadPool::map`], whose jobs must
/// be `'static`, scoped workers may borrow from the caller's stack —
/// the sharded scheduler's step flush hands each worker a mutable slice
/// of pending step tasks plus a forked executor, none of which can
/// escape the flush call. Intended for a handful of coarse shard-sized
/// jobs per call (one OS thread each), not fine-grained fan-out.
pub fn scoped_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> =
            items.into_iter().map(|item| s.spawn(move || f(item))).collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("scoped worker panicked"))
            .collect()
    })
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break Some(job);
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        match job {
            Some(job) => job(),
            None => return,
        }
    }
}

/// A monotonically increasing counter usable across threads (metrics).
#[derive(Default)]
pub struct Counter(AtomicUsize);

impl Counter {
    pub fn incr(&self) -> usize {
        self.0.fetch_add(1, Ordering::Relaxed)
    }
    pub fn add(&self, n: usize) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> usize {
        self.0.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(Counter::default());
        let done = Arc::new((Mutex::new(0usize), Condvar::new()));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let d = Arc::clone(&done);
            pool.execute(move || {
                c.incr();
                let (l, cv) = &*d;
                *l.lock().unwrap() += 1;
                cv.notify_all();
            });
        }
        let (l, cv) = &*done;
        let mut n = l.lock().unwrap();
        while *n < 100 {
            n = cv.wait(n).unwrap();
        }
        assert_eq!(counter.get(), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<_>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_chunked_preserves_order_and_indices() {
        let pool = ThreadPool::new(3);
        // Multi-chunk path (50 items, chunks of 8) and the inline path
        // (4 items <= chunk) must agree with a plain indexed map.
        let want: Vec<usize> = (0..50).map(|i| i * 10 + i).collect();
        let out = pool.map_chunked((0..50).map(|i| i * 10).collect(), 8, |i, x| x + i);
        assert_eq!(out, want);
        let inline = pool.map_chunked((0..4).map(|i| i * 10).collect(), 8, |i, x| x + i);
        assert_eq!(inline, vec![0, 11, 22, 33]);
        let empty: Vec<usize> = pool.map_chunked(Vec::new(), 4, |i, x: usize| x + i);
        assert!(empty.is_empty());
    }

    #[test]
    fn scoped_map_borrows_caller_state() {
        // The whole point over ThreadPool::map: jobs may borrow
        // non-'static state (here, mutable slices of a local vec).
        let mut data = vec![0u64; 12];
        let chunks: Vec<&mut [u64]> = data.chunks_mut(4).collect();
        let lens = scoped_map(chunks, |chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = i as u64 + 1;
            }
            chunk.len()
        });
        assert_eq!(lens, vec![4, 4, 4]);
        assert_eq!(data, vec![1, 2, 3, 4, 1, 2, 3, 4, 1, 2, 3, 4]);
        let empty: Vec<usize> = scoped_map(Vec::<u8>::new(), |b| b as usize);
        assert!(empty.is_empty());
    }

    #[test]
    fn default_workers_clamped() {
        let n = ThreadPool::default_workers();
        assert!((2..=16).contains(&n));
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }
}
