//! Analytical execution models for the comparison platforms.

use crate::sim::report::PlatformResult;
use crate::workload::layers::graph_stats;
use crate::workload::ModelSpec;

use super::params::{self, PlatformParams, DEEPCACHE_COMPUTE_FRACTION};

/// A platform that can execute a diffusion-model generation.
pub trait Platform {
    fn name(&self) -> &str;
    /// Run a full generation of `spec` and report throughput/energy.
    fn run(&self, spec: &ModelSpec) -> PlatformResult;
}

/// Roofline-with-utilization model: each op class proceeds at
/// `peak × utilization(class)`; memory stalls stretch runtime; energy is
/// busy power × busy time + stall power × stall time + DRAM traffic.
#[derive(Debug, Clone)]
pub struct AnalyticalPlatform {
    pub params: PlatformParams,
}

impl AnalyticalPlatform {
    pub fn new(params: PlatformParams) -> Self {
        Self { params }
    }

    /// Compute time/energy for a generation that executes `compute_frac`
    /// of the model's nominal per-step ops (1.0 except for DeepCache).
    /// Execute with only `compute_frac` of the nominal per-step ops
    /// (1.0 for plain platforms; DeepCache's cached schedule uses less).
    /// Public as the calibration hook for the bench/tuning harnesses.
    pub fn run_scaled(&self, spec: &ModelSpec, compute_frac: f64) -> PlatformResult {
        let p = &self.params;
        let stats = graph_stats(&spec.trace());
        let steps = spec.timesteps as f64;

        // Executed ops per class (1 MAC = 2 ops).
        let conv_ops = 2.0 * stats.conv_macs as f64 * compute_frac;
        let attn_ops = 2.0 * stats.attention_macs as f64 * compute_frac;
        let lin_ops = 2.0 * stats.linear_macs as f64 * compute_frac;
        let other_macs = stats.macs_per_step
            - stats.conv_macs
            - stats.attention_macs
            - stats.linear_macs;
        let other_ops = 2.0 * other_macs as f64 * compute_frac;
        let executed_ops_per_step = conv_ops + attn_ops + lin_ops + other_ops;

        // Busy time per step: class ops at class rate.
        let peak = p.peak_gops * 1e9;
        let busy_s = conv_ops / (peak * p.utilization.conv)
            + attn_ops / (peak * p.utilization.attention)
            + lin_ops / (peak * p.utilization.linear)
            + other_ops / (peak * p.utilization.other);
        // Stalls stretch wall-clock: busy is (1 − stall_frac) of runtime.
        let step_s = busy_s / (1.0 - p.stall_time_frac);
        let stall_s = step_s - busy_s;

        // Energy: busy at full power, stalls at stall power, plus DRAM.
        let dram_bytes = executed_ops_per_step * p.bytes_per_op;
        let step_energy = p.power_w * busy_s
            + p.power_w * p.stall_power_frac * stall_s
            + dram_bytes * p.dram_energy_per_byte;

        let latency_s = step_s * steps;
        let energy_j = step_energy * steps;
        let total_ops = executed_ops_per_step * steps;
        PlatformResult {
            platform: p.name.to_string(),
            model: spec.id,
            gops: total_ops / latency_s / 1e9,
            epb_j_per_bit: energy_j / (total_ops * 8.0),
            latency_s,
            energy_j,
        }
    }
}

impl Platform for AnalyticalPlatform {
    fn name(&self) -> &str {
        self.params.name
    }

    fn run(&self, spec: &ModelSpec) -> PlatformResult {
        self.run_scaled(spec, 1.0)
    }
}

/// DeepCache [21]: GPU execution with high-level feature caching — only a
/// fraction of each step's nominal compute executes, but every step pays
/// heavy cached-feature DRAM traffic (the approach's documented
/// scalability limit).
#[derive(Debug, Clone)]
pub struct DeepCachePlatform {
    inner: AnalyticalPlatform,
}

impl DeepCachePlatform {
    pub fn new() -> Self {
        Self { inner: AnalyticalPlatform::new(params::deepcache()) }
    }
}

impl Default for DeepCachePlatform {
    fn default() -> Self {
        Self::new()
    }
}

impl Platform for DeepCachePlatform {
    fn name(&self) -> &str {
        "DeepCache"
    }

    fn run(&self, spec: &ModelSpec) -> PlatformResult {
        self.inner.run_scaled(spec, DEEPCACHE_COMPUTE_FRACTION)
    }
}

/// All six baselines in the paper's Figure 9/10 order.
pub fn all_baselines() -> Vec<Box<dyn Platform>> {
    vec![
        Box::new(AnalyticalPlatform::new(params::cpu_xeon())),
        Box::new(AnalyticalPlatform::new(params::gpu_rtx4070())),
        Box::new(DeepCachePlatform::new()),
        Box::new(AnalyticalPlatform::new(params::fpga_acc1())),
        Box::new(AnalyticalPlatform::new(params::fpga_acc2())),
        Box::new(AnalyticalPlatform::new(params::pace())),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ModelId;

    fn spec() -> ModelSpec {
        ModelSpec::get(ModelId::StableDiffusion)
    }

    #[test]
    fn all_six_baselines_present_in_order() {
        let names: Vec<String> =
            all_baselines().iter().map(|b| b.name().to_string()).collect();
        assert_eq!(
            names,
            ["CPU", "GPU", "DeepCache", "FPGA_Acc1", "FPGA_Acc2", "PACE"]
        );
    }

    #[test]
    fn results_are_finite_and_positive() {
        for b in all_baselines() {
            for id in ModelId::ALL {
                let r = b.run(&ModelSpec::get(id));
                assert!(r.gops > 0.0 && r.gops.is_finite(), "{} gops", r.platform);
                assert!(r.epb_j_per_bit > 0.0 && r.epb_j_per_bit.is_finite());
                assert!(r.latency_s > 0.0 && r.energy_j > 0.0);
            }
        }
    }

    #[test]
    fn gpu_outperforms_cpu_in_throughput() {
        let cpu = AnalyticalPlatform::new(params::cpu_xeon()).run(&spec());
        let gpu = AnalyticalPlatform::new(params::gpu_rtx4070()).run(&spec());
        assert!(gpu.gops > cpu.gops);
    }

    #[test]
    fn deepcache_trails_gpu_in_gops_and_epb() {
        // Paper Fig. 9/10: DeepCache's executed-op throughput and EPB are
        // *worse* than the plain GPU (192× vs 51.89× behind DiffLight in
        // GOPS; 376× vs 94.18× in EPB) — the cached features' memory
        // traffic dominates.
        let gpu = AnalyticalPlatform::new(params::gpu_rtx4070()).run(&spec());
        let dc = DeepCachePlatform::new().run(&spec());
        assert!(dc.gops < gpu.gops);
        assert!(dc.epb_j_per_bit > gpu.epb_j_per_bit);
    }

    #[test]
    fn fpga2_beats_fpga1() {
        let f1 = AnalyticalPlatform::new(params::fpga_acc1()).run(&spec());
        let f2 = AnalyticalPlatform::new(params::fpga_acc2()).run(&spec());
        assert!(f2.gops > f1.gops);
        assert!(f2.epb_j_per_bit < f1.epb_j_per_bit);
    }

    #[test]
    fn pace_is_strongest_baseline_in_gops() {
        let spec = spec();
        let results: Vec<PlatformResult> =
            all_baselines().iter().map(|b| b.run(&spec)).collect();
        let pace = results.iter().find(|r| r.platform == "PACE").unwrap();
        for r in &results {
            assert!(pace.gops >= r.gops, "PACE must lead baselines ({} leads)", r.platform);
        }
    }

    #[test]
    fn cpu_slower_and_hungrier_than_gpu_on_every_model() {
        for id in ModelId::ALL {
            let spec = ModelSpec::get(id);
            let cpu = AnalyticalPlatform::new(params::cpu_xeon()).run(&spec);
            let gpu = AnalyticalPlatform::new(params::gpu_rtx4070()).run(&spec);
            assert!(cpu.latency_s > gpu.latency_s, "{:?}", id);
        }
    }
}
