//! The Residual and MHA units and the whole accelerator (paper Fig. 3).
//!
//! * **Residual unit** — `Y` conv/norm blocks working tile-parallel on the
//!   same layer, plus one activation block.
//! * **MHA unit** — `H` attention-head blocks (heads beyond `H` execute in
//!   serialized rounds) plus the single linear & add block.
//!
//! The ECU splits every convolution's output rows across the `Y` blocks
//! and every attention layer's heads across the `H` head blocks.

use crate::devices::DeviceParams;

use super::activation::ActivationBlock;
use super::attention::{AttentionDims, AttentionHeadBlock};
use super::bank_array::Gemm;
use super::config::ArchConfig;
use super::conv_norm::ConvNormBlock;
use super::cost::{Cost, OptFlags};
use super::linear_add::LinearAddBlock;

/// The Residual unit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResidualUnit {
    pub blocks: usize,
    pub block: ConvNormBlock,
    pub activation: ActivationBlock,
}

impl ResidualUnit {
    pub fn new(cfg: &ArchConfig) -> Self {
        Self {
            blocks: cfg.y,
            block: ConvNormBlock::new(cfg.k, cfg.n, cfg.wavelengths),
            activation: ActivationBlock::new(cfg.wavelengths),
        }
    }

    /// Price a GEMM split row-wise across the `Y` parallel blocks.
    pub fn gemm_cost(&self, gemm: &Gemm, p: &DeviceParams, opts: OptFlags) -> Cost {
        if gemm.m == 0 || gemm.k_d == 0 || gemm.n_out == 0 {
            return Cost::ZERO;
        }
        let rows_per_block = gemm.m.div_ceil(self.blocks);
        let mut total = Cost::ZERO;
        let mut remaining = gemm.m;
        for _ in 0..self.blocks {
            let m = rows_per_block.min(remaining);
            if m == 0 {
                break;
            }
            remaining -= m;
            let shard = Gemm { m, ..*gemm };
            total = total.join(self.block.gemm_cost(&shard, p, opts));
        }
        total
    }

    /// Price a GroupNorm over the unit (statistics span all blocks, so it
    /// executes on one block's norm path for the whole feature map).
    pub fn norm_cost(&self, elements: usize, groups: usize, p: &DeviceParams) -> Cost {
        self.block.norm_cost(elements, groups, p)
    }

    /// Price a swish activation over `elements`.
    pub fn swish_cost(&self, elements: usize, p: &DeviceParams, opts: OptFlags) -> Cost {
        self.activation.swish_cost(elements, p, opts)
    }

    /// Price a residual skip add over `elements`.
    pub fn residual_add_cost(&self, elements: usize, p: &DeviceParams) -> Cost {
        self.activation.residual_add_cost(elements, p)
    }
}

/// The MHA unit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MhaUnit {
    pub head_blocks: usize,
    pub head: AttentionHeadBlock,
    pub linear_add: LinearAddBlock,
}

impl MhaUnit {
    pub fn new(cfg: &ArchConfig) -> Self {
        Self {
            head_blocks: cfg.h,
            head: AttentionHeadBlock::new(cfg.m, cfg.l, cfg.n, cfg.wavelengths),
            linear_add: LinearAddBlock::new(cfg.m, cfg.l, cfg.wavelengths),
        }
    }

    /// Price a full multi-head attention layer with `num_heads` heads:
    /// heads run `H` at a time in rounds, then concat feeds the linear &
    /// add block.
    pub fn mha_cost(
        &self,
        num_heads: usize,
        dims: &AttentionDims,
        p: &DeviceParams,
        opts: OptFlags,
    ) -> Cost {
        if num_heads == 0 || dims.seq == 0 {
            return Cost::ZERO;
        }
        let one_head = self.head.head_cost(dims, p, opts);
        // Work-conserving head scheduling: the H head blocks pick up the
        // next pending head as they drain, so the phase stretches by
        // num_heads/H (≥ 1) rather than by whole-round barriers.
        let stretch = (num_heads as f64 / self.head_blocks as f64).max(1.0);
        let heads_parallel_energy = one_head.energy_j * num_heads as f64;
        let head_phase = Cost {
            latency_s: one_head.latency_s * stretch,
            energy_j: heads_parallel_energy,
            ops: one_head.ops * num_heads as u64,
            passes: one_head.passes * num_heads as u64,
        };
        let concat_dim = num_heads * dims.d_v;
        let linear = self
            .linear_add
            .cost(dims.seq, concat_dim, dims.d_model, p, opts);
        head_phase.then(linear)
    }
}

/// The full DiffLight accelerator: both units under one config.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Accelerator {
    pub config: ArchConfig,
    pub residual: ResidualUnit,
    pub mha: MhaUnit,
}

impl Accelerator {
    pub fn new(config: ArchConfig, params: &DeviceParams) -> crate::Result<Self> {
        config.validate(params)?;
        Ok(Self {
            config,
            residual: ResidualUnit::new(&config),
            mha: MhaUnit::new(&config),
        })
    }

    /// The paper's DSE-optimal instance.
    pub fn paper_optimal(params: &DeviceParams) -> Self {
        Self::new(ArchConfig::paper_optimal(), params).expect("paper config is valid")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> DeviceParams {
        DeviceParams::paper()
    }

    fn acc() -> Accelerator {
        Accelerator::paper_optimal(&p())
    }

    #[test]
    fn residual_parallelism_reduces_latency() {
        let a = acc();
        let single_cfg = ArchConfig::from_vector([1, 12, 3, 6, 6, 3], 36);
        let single = ResidualUnit::new(&single_cfg);
        let g = Gemm::dense(256, 576, 64);
        let par = a.residual.gemm_cost(&g, &p(), OptFlags::ALL);
        let ser = single.gemm_cost(&g, &p(), OptFlags::ALL);
        assert!(par.latency_s < ser.latency_s);
        // Same useful work either way.
        assert_eq!(par.ops, ser.ops);
    }

    #[test]
    fn residual_shards_cover_all_rows() {
        let a = acc();
        let g = Gemm::dense(10, 36, 12); // 10 rows over 4 blocks: 3,3,3,1
        let c = a.residual.gemm_cost(&g, &p(), OptFlags::BASELINE);
        assert_eq!(c.ops, 2 * 10 * 36 * 12);
    }

    #[test]
    fn mha_rounds_serialize_excess_heads() {
        let a = acc();
        let dims = AttentionDims::self_attn(64, 128, 8);
        let six = a.mha.mha_cost(6, &dims, &p(), OptFlags::ALL);
        let twelve = a.mha.mha_cost(12, &dims, &p(), OptFlags::ALL);
        // 12 heads on 6 blocks stretch the head phase ~2× (work-conserving).
        assert!(twelve.latency_s > six.latency_s * 1.5);
        assert!(twelve.energy_j > six.energy_j * 1.7);
    }

    #[test]
    fn mha_zero_heads_free() {
        let a = acc();
        let dims = AttentionDims::self_attn(64, 128, 8);
        assert_eq!(a.mha.mha_cost(0, &dims, &p(), OptFlags::ALL), Cost::ZERO);
    }

    #[test]
    fn accelerator_rejects_invalid_config() {
        let bad = ArchConfig::from_vector([4, 12, 3, 6, 6, 3], 99);
        assert!(Accelerator::new(bad, &p()).is_err());
    }

    #[test]
    fn optimizations_reduce_energy_on_composite_workload() {
        let a = acc();
        let g = Gemm { m: 256, k_d: 576, n_out: 128, zero_fraction: 0.6 };
        let dims = AttentionDims::self_attn(256, 128, 8);
        let base = a
            .residual
            .gemm_cost(&g, &p(), OptFlags::BASELINE)
            .then(a.mha.mha_cost(8, &dims, &p(), OptFlags::BASELINE));
        let all = a
            .residual
            .gemm_cost(&g, &p(), OptFlags::ALL)
            .then(a.mha.mha_cost(8, &dims, &p(), OptFlags::ALL));
        assert!(
            all.energy_j < base.energy_j / 1.8,
            "combined opts: {:.2}x",
            base.energy_j / all.energy_j
        );
    }
}
