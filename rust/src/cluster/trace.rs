//! Opt-in per-request flight recorder for the fleet schedulers.
//!
//! A [`TraceSink`] threaded through [`super::StepScheduler`] and
//! [`super::ReferenceScheduler`] captures every request lifecycle
//! decision — `admit` / `route` / `steal` / `requeue` / `shed` /
//! `step` / `complete` — plus fleet churn — `fault` / `recover` /
//! `migrate` — plus the resilience tier — `retry` / `hedge` /
//! `cancel` / `degrade` — stamped with simulated time, device, request
//! id and service class (churn events carry only the fields they
//! have). Recording is a plain `Vec` push of a `Copy`
//! struct (no formatting, no I/O) so the recorder stays within the
//! ≤5% events/sec overhead gate on the 64-device bench; JSON-lines
//! serialization happens once, after the serve window, via
//! [`TraceSink::write_jsonl`].
//!
//! [`replay`] reconstructs a run's [`FleetMetrics`] distributions from
//! a trace alone: `complete` events carry exactly the tuple the live
//! metrics fold consumes (latency, queue wait, class, deadline
//! verdict, device), and the fold order is normalized the same way the
//! live scheduler normalizes it (completions sorted by `(t, id)`), so
//! the replayed histograms are **bit-identical** to the live run's —
//! same buckets, same counts, same quantiles. [`diff`] compares two
//! traces: first divergent event plus per-device routing deltas.

use std::io::Write;

use crate::util::histogram::LogHistogram;
use crate::util::json::Json;

use super::metrics::{DeviceMetrics, FleetMetrics, MigrateOutcome};

/// One scheduler decision, stamped with simulated time `t`, request
/// `id` and service `class`. `Copy` so recording is a buffer push.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceEvent {
    /// A request entered admission control.
    Admit { t: f64, id: u64, class: u8 },
    /// The router placed the request on `device`'s admission queue;
    /// `est_s` is the admission-time completion estimate quoted for
    /// that placement (occupancy × drain weight, generation-scaled).
    Route { t: f64, id: u64, class: u8, device: usize, est_s: f64 },
    /// Work stealing moved the queued request from donor `from` to
    /// thief `device` at a step boundary.
    Steal { t: f64, id: u64, class: u8, device: usize, from: usize },
    /// Every device was full; the request was deferred to the
    /// fleet-level backlog for re-routing at the next step boundary.
    Requeue { t: f64, id: u64, class: u8 },
    /// Admission control dropped the request, attributed to `device`
    /// (`-1` when no up device existed to attribute it to — a total
    /// outage; counted in the fleet `shed_unattributed` bucket);
    /// `tracked` marks a request that carried a deadline (an SLO miss).
    Shed { t: f64, id: u64, class: u8, device: i64, tracked: bool },
    /// The request participated in a fused denoise step on `device`
    /// (`full` distinguishes full-UNet from DeepCache shallow steps).
    Step { t: f64, id: u64, class: u8, device: usize, full: bool },
    /// The request finished. `device` is `-1` for zero-step requests,
    /// which complete at admission without touching a device. Carries
    /// the full tuple the metrics fold consumes, so a trace alone can
    /// rebuild the run's latency/queue distributions bit-identically.
    Complete {
        t: f64,
        id: u64,
        class: u8,
        device: i64,
        latency_s: f64,
        queue_s: f64,
        deadline_met: Option<bool>,
    },
    /// A fault fired on `device` (a fleet event — no request id/class).
    /// Recorded at the simulated instant the fault *takes effect*: for
    /// a busy device that is the step boundary where its in-flight
    /// work retires, not the instant the plan scheduled it.
    Fault { t: f64, device: usize, fault: TraceFault },
    /// `device` came back up after a recalibration outage and rejoined
    /// the routable fleet.
    Recover { t: f64, device: usize },
    /// A victim of a fault on `from` was re-admitted. `to` is the new
    /// device (`-1`: deferred to the fleet backlog, `-2`: lost — no
    /// capacity or doomed under its deadline, `-3`: handed back to the
    /// client retry tier for resubmission). `resident` marks an
    /// interrupted in-flight sample (vs one still queued on `from`).
    Migrate { t: f64, id: u64, class: u8, from: usize, to: i64, resident: bool },
    /// A failed (shed or fault-lost) request was accepted by the client
    /// retry tier: resubmission `attempt` (1 = first retry) re-enters
    /// the arrival stream at `at_s` after its jittered backoff.
    Retry { t: f64, id: u64, class: u8, attempt: u32, at_s: f64 },
    /// The request straggled past the hedge threshold on `from`; a
    /// duplicate copy was issued to `to`. First copy to retire wins.
    Hedge { t: f64, id: u64, class: u8, from: usize, to: usize },
    /// The losing copy of a hedged request was cancelled on `device`
    /// at its next step boundary, after `steps` duplicated denoise
    /// steps (the duplicate-work cost of the hedge).
    Cancel { t: f64, id: u64, class: u8, device: usize, steps: u64 },
    /// The brownout controller admitted the request degraded: served
    /// with `steps` denoise steps (down from its requested count) at
    /// degradation `level`.
    Degrade { t: f64, id: u64, class: u8, level: u32, steps: u64 },
}

/// What happened to the device in a [`TraceEvent::Fault`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceFault {
    /// Permanent loss: the die never rejoins the fleet.
    Crash,
    /// Thermal-recalibration outage: down until `until_s`.
    Outage { until_s: f64 },
    /// Straggler onset: all subsequent steps (and the drain weight)
    /// are slowed by `factor`.
    Slow { factor: f64 },
}

impl TraceEvent {
    /// The event-kind tag used in the JSON-lines encoding.
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::Admit { .. } => "admit",
            TraceEvent::Route { .. } => "route",
            TraceEvent::Steal { .. } => "steal",
            TraceEvent::Requeue { .. } => "requeue",
            TraceEvent::Shed { .. } => "shed",
            TraceEvent::Step { .. } => "step",
            TraceEvent::Complete { .. } => "complete",
            TraceEvent::Fault { .. } => "fault",
            TraceEvent::Recover { .. } => "recover",
            TraceEvent::Migrate { .. } => "migrate",
            TraceEvent::Retry { .. } => "retry",
            TraceEvent::Hedge { .. } => "hedge",
            TraceEvent::Cancel { .. } => "cancel",
            TraceEvent::Degrade { .. } => "degrade",
        }
    }

    /// Simulated timestamp of the event.
    pub fn time_s(&self) -> f64 {
        match *self {
            TraceEvent::Admit { t, .. }
            | TraceEvent::Route { t, .. }
            | TraceEvent::Steal { t, .. }
            | TraceEvent::Requeue { t, .. }
            | TraceEvent::Shed { t, .. }
            | TraceEvent::Step { t, .. }
            | TraceEvent::Complete { t, .. }
            | TraceEvent::Fault { t, .. }
            | TraceEvent::Recover { t, .. }
            | TraceEvent::Migrate { t, .. }
            | TraceEvent::Retry { t, .. }
            | TraceEvent::Hedge { t, .. }
            | TraceEvent::Cancel { t, .. }
            | TraceEvent::Degrade { t, .. } => t,
        }
    }

    /// The device whose shard tags this event in the v3 serialization
    /// (`None`: deviceless lifecycle events, or the `-1` no-device
    /// sentinel on shed/complete). Events that name two devices tag
    /// with the one that *owned* the decision: the donor shard for a
    /// migration, the straggler's shard for a hedge, the thief's for a
    /// steal (the steal lands on the thief's queue).
    pub fn shard_device(&self) -> Option<usize> {
        match *self {
            TraceEvent::Admit { .. }
            | TraceEvent::Requeue { .. }
            | TraceEvent::Retry { .. }
            | TraceEvent::Degrade { .. } => None,
            TraceEvent::Route { device, .. }
            | TraceEvent::Steal { device, .. }
            | TraceEvent::Step { device, .. }
            | TraceEvent::Fault { device, .. }
            | TraceEvent::Recover { device, .. }
            | TraceEvent::Cancel { device, .. } => Some(device),
            TraceEvent::Migrate { from, .. } | TraceEvent::Hedge { from, .. } => Some(from),
            TraceEvent::Shed { device, .. } | TraceEvent::Complete { device, .. } => {
                usize::try_from(device).ok()
            }
        }
    }

    /// One JSON object per event (`{"ev":...,"t":...}` plus `id` /
    /// `class` for request-lifecycle events and kind-specific fields).
    /// `f64`s go through the shortest-round-trip formatter, so parsing
    /// recovers the exact bits — the foundation of replay bit-identity.
    pub fn to_json(&self) -> Json {
        let base = Json::obj().set("ev", self.kind()).set("t", self.time_s());
        // Fleet churn events carry no request id/class.
        match *self {
            TraceEvent::Fault { device, fault, .. } => {
                let j = base.set("dev", device);
                return match fault {
                    TraceFault::Crash => j.set("kind", "crash"),
                    TraceFault::Outage { until_s } => {
                        j.set("kind", "outage").set("until", until_s)
                    }
                    TraceFault::Slow { factor } => j.set("kind", "slow").set("factor", factor),
                };
            }
            TraceEvent::Recover { device, .. } => return base.set("dev", device),
            _ => {}
        }
        let (id, class) = match *self {
            TraceEvent::Admit { id, class, .. }
            | TraceEvent::Route { id, class, .. }
            | TraceEvent::Steal { id, class, .. }
            | TraceEvent::Requeue { id, class, .. }
            | TraceEvent::Shed { id, class, .. }
            | TraceEvent::Step { id, class, .. }
            | TraceEvent::Complete { id, class, .. }
            | TraceEvent::Migrate { id, class, .. }
            | TraceEvent::Retry { id, class, .. }
            | TraceEvent::Hedge { id, class, .. }
            | TraceEvent::Cancel { id, class, .. }
            | TraceEvent::Degrade { id, class, .. } => (id, class),
            TraceEvent::Fault { .. } | TraceEvent::Recover { .. } => unreachable!(),
        };
        let j = base.set("id", id).set("class", class);
        match *self {
            TraceEvent::Admit { .. } | TraceEvent::Requeue { .. } => j,
            TraceEvent::Route { device, est_s, .. } => j.set("dev", device).set("est", est_s),
            TraceEvent::Steal { device, from, .. } => j.set("dev", device).set("from", from),
            TraceEvent::Shed { device, tracked, .. } => {
                j.set("dev", device).set("tracked", tracked)
            }
            TraceEvent::Step { device, full, .. } => j.set("dev", device).set("full", full),
            TraceEvent::Complete { device, latency_s, queue_s, deadline_met, .. } => j
                .set("dev", device)
                .set("latency_s", latency_s)
                .set("queue_s", queue_s)
                .set(
                    "deadline_met",
                    deadline_met.map_or(Json::Null, Json::Bool),
                ),
            TraceEvent::Migrate { from, to, resident, .. } => {
                j.set("from", from).set("to", to).set("resident", resident)
            }
            TraceEvent::Retry { attempt, at_s, .. } => {
                j.set("attempt", attempt).set("at", at_s)
            }
            TraceEvent::Hedge { from, to, .. } => j.set("from", from).set("to", to),
            TraceEvent::Cancel { device, steps, .. } => {
                j.set("dev", device).set("steps", steps)
            }
            TraceEvent::Degrade { level, steps, .. } => {
                j.set("level", level).set("steps", steps)
            }
            TraceEvent::Fault { .. } | TraceEvent::Recover { .. } => unreachable!(),
        }
    }

    /// Decode one parsed JSON-lines object back into an event.
    pub fn from_json(j: &Json) -> Result<Self, String> {
        let num = |k: &str| {
            j.get(k).and_then(Json::as_f64).ok_or_else(|| format!("missing number '{k}'"))
        };
        let t = num("t")?;
        let dev = || num("dev").map(|d| d as usize);
        // Churn events carry no request id/class — decode them before
        // the request-lifecycle kinds demand those fields.
        match j.get("ev").and_then(Json::as_str).ok_or("missing 'ev' tag")? {
            "fault" => {
                let device = dev()?;
                let fault = match j.get("kind").and_then(Json::as_str) {
                    Some("crash") => TraceFault::Crash,
                    Some("outage") => TraceFault::Outage { until_s: num("until")? },
                    Some("slow") => TraceFault::Slow { factor: num("factor")? },
                    Some(other) => return Err(format!("unknown fault kind '{other}'")),
                    None => return Err("fault event missing 'kind'".to_string()),
                };
                return Ok(TraceEvent::Fault { t, device, fault });
            }
            "recover" => return Ok(TraceEvent::Recover { t, device: dev()? }),
            _ => {}
        }
        let id = num("id")? as u64;
        let class = num("class")? as u8;
        match j.get("ev").and_then(Json::as_str).ok_or("missing 'ev' tag")? {
            "admit" => Ok(TraceEvent::Admit { t, id, class }),
            "requeue" => Ok(TraceEvent::Requeue { t, id, class }),
            "route" => Ok(TraceEvent::Route { t, id, class, device: dev()?, est_s: num("est")? }),
            "steal" => Ok(TraceEvent::Steal {
                t,
                id,
                class,
                device: dev()?,
                from: num("from")? as usize,
            }),
            "shed" => {
                let tracked = matches!(j.get("tracked"), Some(Json::Bool(true)));
                Ok(TraceEvent::Shed { t, id, class, device: num("dev")? as i64, tracked })
            }
            "step" => {
                let full = matches!(j.get("full"), Some(Json::Bool(true)));
                Ok(TraceEvent::Step { t, id, class, device: dev()?, full })
            }
            "complete" => Ok(TraceEvent::Complete {
                t,
                id,
                class,
                device: num("dev")? as i64,
                latency_s: num("latency_s")?,
                queue_s: num("queue_s")?,
                deadline_met: match j.get("deadline_met") {
                    Some(Json::Bool(b)) => Some(*b),
                    _ => None,
                },
            }),
            "migrate" => Ok(TraceEvent::Migrate {
                t,
                id,
                class,
                from: num("from")? as usize,
                to: num("to")? as i64,
                resident: matches!(j.get("resident"), Some(Json::Bool(true))),
            }),
            "retry" => Ok(TraceEvent::Retry {
                t,
                id,
                class,
                attempt: num("attempt")? as u32,
                at_s: num("at")?,
            }),
            "hedge" => Ok(TraceEvent::Hedge {
                t,
                id,
                class,
                from: num("from")? as usize,
                to: num("to")? as usize,
            }),
            "cancel" => Ok(TraceEvent::Cancel {
                t,
                id,
                class,
                device: dev()?,
                steps: num("steps")? as u64,
            }),
            "degrade" => Ok(TraceEvent::Degrade {
                t,
                id,
                class,
                level: num("level")? as u32,
                steps: num("steps")? as u64,
            }),
            other => Err(format!("unknown event kind '{other}'")),
        }
    }
}

/// The flight recorder: an in-memory event buffer owned by a scheduler
/// for the duration of a serve window. Recording never formats or
/// writes — serialization is a separate, post-serve pass.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceSink {
    events: Vec<TraceEvent>,
    /// Device → shard lookup installed by the sharded scheduler
    /// (`ShardMap::assignments`). When present, serialization stamps
    /// every device-carrying event with an optional `"shard"` field
    /// (schema v3). Purely a serialization-layer annotation: in-memory
    /// events — and therefore `events()`, replay and diff — stay
    /// shard-count-invariant.
    shards: Vec<u32>,
}

impl TraceSink {
    pub fn new() -> Self {
        Self::default()
    }

    /// Install the device → shard lookup used to stamp the optional
    /// `"shard"` field on serialized events. Survives [`TraceSink::clear`]
    /// (the layout outlives any one serve window).
    pub fn set_shard_map(&mut self, shards: Vec<u32>) {
        self.shards = shards;
    }

    #[inline]
    pub fn record(&mut self, ev: TraceEvent) {
        self.events.push(ev);
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn clear(&mut self) {
        self.events.clear();
    }

    /// Serialize one event, stamping the optional `"shard"` field when
    /// a shard map is installed and the event names a device. Shed and
    /// complete events can carry the `-1` no-device sentinel; those —
    /// and deviceless lifecycle events (admit/requeue/retry/degrade) —
    /// stay untagged, exactly like every event of a pre-v3 trace.
    fn event_json(&self, ev: &TraceEvent) -> Json {
        let j = ev.to_json();
        match ev.shard_device().and_then(|d| self.shards.get(d)) {
            Some(&shard) => j.set("shard", shard),
            None => j,
        }
    }

    /// The JSON-lines encoding: the versioned header line, then one
    /// compact object per event.
    pub fn to_jsonl(&self) -> String {
        let mut out = header_line();
        out.push('\n');
        for ev in &self.events {
            out.push_str(&self.event_json(ev).to_string_compact());
            out.push('\n');
        }
        out
    }

    /// Stream the JSON-lines encoding (header included) to a writer.
    pub fn write_jsonl(&self, out: &mut dyn Write) -> std::io::Result<()> {
        writeln!(out, "{}", header_line())?;
        for ev in &self.events {
            writeln!(out, "{}", self.event_json(ev).to_string_compact())?;
        }
        Ok(())
    }
}

/// Record into an optional sink. A free function (not a scheduler
/// method) so call sites inside field-borrowing loops — e.g. the
/// retire loop draining `self.resident[di]` — can split-borrow just
/// the trace field.
#[inline]
pub(super) fn emit(trace: &mut Option<TraceSink>, ev: TraceEvent) {
    if let Some(sink) = trace {
        sink.record(ev);
    }
}

/// Trace schema version stamped in the header line of every trace
/// this build writes. Bumped whenever the event vocabulary or field
/// layout changes, so a replayer never silently misreads an
/// old-schema file. Version 2 added the resilience-tier events
/// (`retry` / `hedge` / `cancel` / `degrade`) and the header itself;
/// version 3 added the optional per-event `shard` tag (sharded event
/// core). v2 traces differ only by the absence of that optional field,
/// so this build still reads them ([`MIN_TRACE_VERSION`]) with the
/// field defaulted to untagged.
pub const TRACE_VERSION: u64 = 3;

/// Oldest trace schema this build still reads (v2: identical layout
/// minus the optional `shard` tag).
pub const MIN_TRACE_VERSION: u64 = 2;

/// The header line [`TraceSink::to_jsonl`] writes.
fn header_line() -> String {
    format!("{{\"trace\":\"difflight\",\"version\":{TRACE_VERSION}}}")
}

/// Validate a parsed header object against [`TRACE_VERSION`].
fn check_header(j: &Json) -> Result<(), String> {
    if j.get("trace").and_then(Json::as_str) != Some("difflight") {
        return Err("bad trace header: expected \"trace\":\"difflight\"".to_string());
    }
    match j.get("version").and_then(Json::as_f64) {
        Some(v) if v >= MIN_TRACE_VERSION as f64 && v <= TRACE_VERSION as f64 => Ok(()),
        Some(v) => Err(format!(
            "unsupported trace version {v} (this build reads versions \
             {MIN_TRACE_VERSION}-{TRACE_VERSION}); re-record the trace"
        )),
        None => Err("trace header missing 'version'".to_string()),
    }
}

/// Parse a JSON-lines trace document (blank lines ignored). A leading
/// `{"trace":"difflight","version":N}` header is validated and
/// skipped when present; headerless event streams still parse, so
/// in-memory round trips and hand-built fixtures stay cheap. The
/// `trace replay` CLI uses the strict [`parse_jsonl_versioned`]
/// instead, which *requires* the header.
pub fn parse_jsonl(text: &str) -> Result<Vec<TraceEvent>, String> {
    let mut events = Vec::new();
    let mut first = true;
    for (n, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| format!("trace line {}: {e}", n + 1))?;
        if std::mem::take(&mut first) && j.get("trace").is_some() {
            check_header(&j).map_err(|e| format!("trace line {}: {e}", n + 1))?;
            continue;
        }
        events.push(TraceEvent::from_json(&j).map_err(|e| format!("trace line {}: {e}", n + 1))?);
    }
    Ok(events)
}

/// Parse a JSON-lines trace document, *requiring* the versioned
/// header [`TraceSink::to_jsonl`] writes. Headerless files — traces
/// recorded before the schema carried a version — are rejected
/// loudly, so `trace replay` can never misinterpret an old-schema
/// file as a current one.
pub fn parse_jsonl_versioned(text: &str) -> Result<Vec<TraceEvent>, String> {
    let has_header = text
        .lines()
        .find(|l| !l.trim().is_empty())
        .and_then(|l| Json::parse(l).ok())
        .map_or(false, |j| j.get("trace").is_some());
    if !has_header {
        return Err(format!(
            "missing versioned trace header (expected {} on line 1) — this file predates \
             the trace schema version stamp; re-record it with this build",
            header_line()
        ));
    }
    parse_jsonl(text)
}

/// A run reconstructed from its trace alone.
#[derive(Debug, Clone)]
pub struct TraceReplay {
    /// Distributional metrics recomputed from the trace: latency and
    /// queue histograms (fleet, per-class, per-device), admission
    /// estimates, shed attribution, makespan, completion/shed counts.
    /// Bit-identical to the live run's wherever the trace carries the
    /// inputs; purely device-side accounting (busy time, energy, ops)
    /// is not in the trace and stays zero.
    pub metrics: FleetMetrics,
    /// Routing decisions per device (admission placements, not steals).
    pub route_counts: Vec<u64>,
}

/// Rebuild a run's distributional metrics from its trace.
///
/// The fold mirrors the live schedulers exactly: completions sorted by
/// `(t, id)` (the live result sort), then sheds in recorded order —
/// so every histogram receives the same values in the same order and
/// ends up bit-identical, `sum` included.
pub fn replay(events: &[TraceEvent]) -> TraceReplay {
    let mut ndev = 0usize;
    for ev in events {
        let d = match *ev {
            TraceEvent::Route { device, .. }
            | TraceEvent::Step { device, .. }
            | TraceEvent::Fault { device, .. }
            | TraceEvent::Recover { device, .. } => device as i64,
            TraceEvent::Steal { device, from, .. } => device.max(from) as i64,
            TraceEvent::Shed { device, .. } | TraceEvent::Complete { device, .. } => device,
            TraceEvent::Migrate { from, to, .. } => (from as i64).max(to),
            TraceEvent::Hedge { from, to, .. } => from.max(to) as i64,
            TraceEvent::Cancel { device, .. } => device as i64,
            _ => -1,
        };
        if d >= 0 {
            ndev = ndev.max(d as usize + 1);
        }
    }
    let mut metrics = FleetMetrics {
        devices: (0..ndev).map(|i| DeviceMetrics { id: i, ..Default::default() }).collect(),
        ..Default::default()
    };
    let mut route_counts = vec![0u64; ndev];

    let mut first_arrival_s = f64::INFINITY;
    let mut last_finish_s = 0.0f64;
    let mut completes: Vec<(f64, u64, u8, i64, f64, f64, Option<bool>)> = Vec::new();
    let mut down_since: Vec<Option<f64>> = vec![None; ndev];
    for ev in events {
        match *ev {
            TraceEvent::Admit { t, .. } => first_arrival_s = first_arrival_s.min(t),
            TraceEvent::Route { device, est_s, .. } => {
                metrics.devices[device].admission_est.record(est_s);
                route_counts[device] += 1;
            }
            TraceEvent::Complete { t, id, class, device, latency_s, queue_s, deadline_met } => {
                last_finish_s = last_finish_s.max(t);
                completes.push((t, id, class, device, latency_s, queue_s, deadline_met));
            }
            TraceEvent::Fault { t, device, fault } => match fault {
                TraceFault::Crash | TraceFault::Outage { .. } => down_since[device] = Some(t),
                TraceFault::Slow { .. } => {}
            },
            TraceEvent::Recover { t, device } => {
                if let Some(since) = down_since[device].take() {
                    metrics.devices[device].downtime_s += (t - since).max(0.0);
                }
            }
            _ => {}
        }
    }
    // Devices still down at the end of the window accrue downtime up
    // to the last completion — the live `finalize_downtime` pass folds
    // over the same `(finish_s, base 0.0)` maximum.
    for (di, since) in down_since.iter().enumerate() {
        if let Some(since) = since {
            metrics.devices[di].downtime_s += (last_finish_s - since).max(0.0);
        }
    }
    completes.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    for &(_, _, class, device, latency_s, queue_s, deadline_met) in &completes {
        let di = if device >= 0 { device as usize } else { usize::MAX };
        metrics.record_completion(latency_s, queue_s, class, deadline_met, di);
        if let Some(d) = metrics.devices.get_mut(di) {
            d.samples_completed += 1;
        }
    }
    // Sheds fold after completions, in recorded order — exactly the
    // live `shed_log` pass. `dev = -1` is the total-outage sentinel:
    // no device to charge, counted in the fleet-wide bucket.
    for ev in events {
        if let TraceEvent::Shed { class, device, tracked, .. } = *ev {
            metrics.record_shed(class, tracked);
            metrics.rejected += 1;
            if device >= 0 {
                metrics.devices[device as usize].shed += 1;
            } else {
                metrics.shed_unattributed += 1;
            }
        }
    }
    // Migrations fold next, in recorded order — the live `migrate_log`
    // pass. The `from` device owns the churn accounting. A Resubmitted
    // victim left the fleet through the client retry tier: it counts
    // as interrupted, but its class retry is folded from the paired
    // `retry` event below, never here.
    for ev in events {
        if let TraceEvent::Migrate { class, from, to, resident, .. } = *ev {
            let outcome = MigrateOutcome::from_target(to);
            metrics.record_migration(class, resident, outcome);
            let d = &mut metrics.devices[from];
            if resident {
                d.interrupted += 1;
            }
            match outcome {
                MigrateOutcome::Migrated => d.migrated += 1,
                MigrateOutcome::Retried => d.retried += 1,
                MigrateOutcome::Lost => d.lost += 1,
                MigrateOutcome::Resubmitted => {}
            }
        }
    }
    // Resilience-tier folds, in recorded order — the live `retry_log` /
    // `degrade_log` passes plus the direct hedge/cancel device counters.
    for ev in events {
        match *ev {
            TraceEvent::Retry { class, .. } => metrics.record_retry(class),
            TraceEvent::Degrade { class, .. } => metrics.record_degrade(class),
            TraceEvent::Hedge { from, .. } => metrics.devices[from].hedged += 1,
            TraceEvent::Cancel { device, .. } => metrics.devices[device].cancelled += 1,
            _ => {}
        }
    }
    if first_arrival_s.is_finite() {
        metrics.makespan_s = (last_finish_s - first_arrival_s).max(0.0);
    }
    TraceReplay { metrics, route_counts }
}

/// Where two traces disagree.
#[derive(Debug, Clone)]
pub struct TraceDiff {
    /// First index at which the traces diverge, with both events
    /// rendered as JSON lines (`<end of trace>` for the shorter one);
    /// `None` when the traces are identical.
    pub first_divergence: Option<(usize, String, String)>,
    /// Devices whose admission-routing counts differ: `(device,
    /// routes_a, routes_b)`.
    pub route_deltas: Vec<(usize, u64, u64)>,
}

impl TraceDiff {
    pub fn identical(&self) -> bool {
        self.first_divergence.is_none()
    }
}

/// Compare two traces' scheduler decisions: the first divergent event
/// plus per-device routing deltas.
pub fn diff(a: &[TraceEvent], b: &[TraceEvent]) -> TraceDiff {
    let render = |ev: Option<&TraceEvent>| {
        ev.map_or_else(|| "<end of trace>".to_string(), |e| e.to_json().to_string_compact())
    };
    let mut first_divergence = None;
    for i in 0..a.len().max(b.len()) {
        if a.get(i) != b.get(i) {
            first_divergence = Some((i, render(a.get(i)), render(b.get(i))));
            break;
        }
    }
    let (ra, rb) = (replay(a), replay(b));
    let mut route_deltas = Vec::new();
    for d in 0..ra.route_counts.len().max(rb.route_counts.len()) {
        let ca = ra.route_counts.get(d).copied().unwrap_or(0);
        let cb = rb.route_counts.get(d).copied().unwrap_or(0);
        if ca != cb {
            route_deltas.push((d, ca, cb));
        }
    }
    TraceDiff { first_divergence, route_deltas }
}

/// Convenience: the latency/queue quantile summary the `trace replay`
/// CLI prints and the verify gate compares against a live report.
pub fn replay_summary(r: &TraceReplay) -> Json {
    Json::obj()
        .set("samples", r.metrics.samples_completed)
        .set("rejected", r.metrics.rejected)
        .set("makespan_s", r.metrics.makespan_s)
        .set("latency_p50_s", r.metrics.latency_p50_s())
        .set("latency_p99_s", r.metrics.latency_p99_s())
        .set("queue_mean_s", r.metrics.queue_mean_s())
        .set("latency_hist", r.metrics.latency.to_json())
        .set("queue_hist", r.metrics.queue.to_json())
}

/// A replay must agree with the live run on every distributional
/// field the report exports. Compares exact values (the JSON round
/// trip is shortest-round-trip, so equality is bit-equality) and the
/// full histogram encodings; returns the mismatched keys.
pub fn check_against_report(r: &TraceReplay, report: &Json) -> Vec<String> {
    let summary = replay_summary(r);
    let mut bad = Vec::new();
    for key in
        ["samples", "rejected", "makespan_s", "latency_p50_s", "latency_p99_s", "queue_mean_s"]
    {
        if report.get(key).and_then(Json::as_f64) != summary.get(key).and_then(Json::as_f64) {
            bad.push(key.to_string());
        }
    }
    for key in ["latency_hist", "queue_hist"] {
        if report.get(key) != summary.get(key) {
            bad.push(key.to_string());
        }
    }
    bad
}

/// Replayed latency histogram straight from a trace (helper for tests
/// and the bench gates).
pub fn replay_latency_hist(events: &[TraceEvent]) -> LogHistogram {
    replay(events).metrics.latency.clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<TraceEvent> {
        vec![
            TraceEvent::Admit { t: 0.0, id: 1, class: 0 },
            TraceEvent::Route { t: 0.0, id: 1, class: 0, device: 0, est_s: 0.25 },
            TraceEvent::Admit { t: 0.5, id: 2, class: 1 },
            TraceEvent::Requeue { t: 0.5, id: 2, class: 1 },
            TraceEvent::Steal { t: 1.0, id: 2, class: 1, device: 1, from: 0 },
            TraceEvent::Step { t: 1.0, id: 1, class: 0, device: 0, full: true },
            TraceEvent::Shed { t: 1.5, id: 3, class: 2, device: 1, tracked: true },
            TraceEvent::Complete {
                t: 2.0,
                id: 1,
                class: 0,
                device: 0,
                latency_s: 2.0,
                queue_s: 0.125,
                deadline_met: Some(true),
            },
            TraceEvent::Complete {
                t: 2.5,
                id: 2,
                class: 1,
                device: 1,
                latency_s: 2.0,
                queue_s: 0.5,
                deadline_met: None,
            },
        ]
    }

    #[test]
    fn jsonl_round_trips_every_event_kind() {
        let mut sink = TraceSink::new();
        for ev in sample_events() {
            sink.record(ev);
        }
        let text = sink.to_jsonl();
        // One versioned header line, then one line per event.
        assert_eq!(text.lines().count(), sink.len() + 1);
        assert_eq!(text.lines().next(), Some(header_line().as_str()));
        let parsed = parse_jsonl(&text).expect("parse");
        assert_eq!(parsed, sink.events());
        // The strict parser accepts the headered document too.
        assert_eq!(parse_jsonl_versioned(&text).expect("parse"), sink.events());
        // write_jsonl produces the same bytes as to_jsonl.
        let mut buf = Vec::new();
        sink.write_jsonl(&mut buf).unwrap();
        assert_eq!(String::from_utf8(buf).unwrap(), text);
    }

    #[test]
    fn version_header_gates_strict_parsing() {
        // A headerless event stream: lenient parse accepts, strict
        // parse rejects with a loud re-record message.
        let doc = "{\"ev\":\"admit\",\"t\":0,\"id\":1,\"class\":0}\n";
        assert_eq!(parse_jsonl(doc).expect("lenient").len(), 1);
        let err = parse_jsonl_versioned(doc).expect_err("headerless must be rejected");
        assert!(err.contains("missing versioned trace header"), "{err}");
        assert!(err.contains("version"), "{err}");
        // A stale version is rejected by both parsers, naming both
        // versions, on line 1.
        let stale = format!("{{\"trace\":\"difflight\",\"version\":1}}\n{doc}");
        for result in [parse_jsonl(&stale), parse_jsonl_versioned(&stale)] {
            let err = result.expect_err("version 1 must be rejected");
            assert!(err.contains("trace line 1"), "{err}");
            assert!(err.contains("unsupported trace version 1"), "{err}");
            assert!(err.contains(&TRACE_VERSION.to_string()), "{err}");
        }
        // A mangled header (wrong magic, missing version) is loud too.
        let bad = format!("{{\"trace\":\"other\",\"version\":{TRACE_VERSION}}}\n");
        assert!(parse_jsonl(&bad).is_err());
        assert!(parse_jsonl("{\"trace\":\"difflight\"}\n").is_err());
        // Blank lines before the header are fine.
        let padded = format!("\n{}\n{doc}", header_line());
        assert_eq!(parse_jsonl_versioned(&padded).expect("padded").len(), 1);
    }

    #[test]
    fn v2_traces_still_parse_with_shard_defaulted() {
        // A pre-shard (v2) trace differs from v3 only by the absent
        // optional `shard` field: both parsers must accept it and
        // decode the same events a v3 reader sees.
        let body = "{\"ev\":\"admit\",\"t\":0,\"id\":1,\"class\":0}\n\
                    {\"ev\":\"route\",\"t\":0,\"id\":1,\"class\":0,\"dev\":2,\"est\":0.5}\n";
        let v2 = format!("{{\"trace\":\"difflight\",\"version\":2}}\n{body}");
        let v3 = format!("{{\"trace\":\"difflight\",\"version\":3}}\n{body}");
        let from_v2 = parse_jsonl_versioned(&v2).expect("v2 must still parse");
        assert_eq!(from_v2, parse_jsonl_versioned(&v3).expect("v3 parses"));
        assert_eq!(from_v2.len(), 2);
        assert_eq!(parse_jsonl(&v2).expect("lenient v2"), from_v2);
    }

    #[test]
    fn shard_map_tags_device_events_only_and_round_trips() {
        let mut sink = TraceSink::new();
        // Devices 0-1 in shard 0, devices 2-3 in shard 1.
        sink.set_shard_map(vec![0, 0, 1, 1]);
        for ev in [
            TraceEvent::Admit { t: 0.0, id: 1, class: 0 },
            TraceEvent::Route { t: 0.0, id: 1, class: 0, device: 2, est_s: 0.5 },
            TraceEvent::Hedge { t: 1.0, id: 1, class: 0, from: 0, to: 3 },
            TraceEvent::Shed { t: 1.0, id: 2, class: 0, device: -1, tracked: false },
            TraceEvent::Fault { t: 2.0, device: 3, fault: TraceFault::Crash },
        ] {
            sink.record(ev);
        }
        let text = sink.to_jsonl();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], header_line());
        assert!(!lines[1].contains("\"shard\""), "admit carries no device: {}", lines[1]);
        assert!(lines[2].contains("\"shard\":1"), "route tags its device's shard: {}", lines[2]);
        assert!(lines[3].contains("\"shard\":0"), "hedge tags the straggler's shard: {}", lines[3]);
        assert!(!lines[4].contains("\"shard\""), "dev=-1 sentinel stays untagged: {}", lines[4]);
        assert!(lines[5].contains("\"shard\":1"), "fault tags its device's shard: {}", lines[5]);
        // The tag is serialization-only: parsing drops it, so events
        // round-trip identically to an untagged sink's.
        let parsed = parse_jsonl_versioned(&text).expect("tagged trace parses");
        assert_eq!(parsed, sink.events());
        // write_jsonl agrees byte-for-byte, and clear() keeps the map.
        let mut buf = Vec::new();
        sink.write_jsonl(&mut buf).unwrap();
        assert_eq!(String::from_utf8(buf).unwrap(), text);
        sink.clear();
        sink.record(TraceEvent::Recover { t: 3.0, device: 1 });
        assert!(sink.to_jsonl().contains("\"shard\":0"), "map survives clear");
    }

    #[test]
    fn resilience_events_round_trip_and_replay() {
        let mut sink = TraceSink::new();
        for ev in [
            TraceEvent::Admit { t: 0.0, id: 1, class: 1 },
            TraceEvent::Degrade { t: 0.0, id: 1, class: 1, level: 2, steps: 2 },
            TraceEvent::Route { t: 0.0, id: 1, class: 1, device: 0, est_s: 0.25 },
            // Request 1 straggles on device 0; its hedge goes to 1 and
            // wins, so the original copy is cancelled after 3 wasted
            // steps.
            TraceEvent::Hedge { t: 1.0, id: 1, class: 1, from: 0, to: 1 },
            TraceEvent::Cancel { t: 2.0, id: 1, class: 1, device: 0, steps: 3 },
            // Request 2 is shed and accepted for a second attempt.
            TraceEvent::Shed { t: 1.5, id: 2, class: 0, device: 1, tracked: false },
            TraceEvent::Retry { t: 1.5, id: 2, class: 0, attempt: 1, at_s: 1.75 },
            // A fault victim resubmitted through the retry tier.
            TraceEvent::Migrate { t: 2.5, id: 3, class: 1, from: 1, to: -3, resident: true },
            TraceEvent::Retry { t: 2.5, id: 3, class: 1, attempt: 2, at_s: 2.9 },
        ] {
            sink.record(ev);
        }
        let text = sink.to_jsonl();
        assert_eq!(parse_jsonl(&text).expect("parse"), sink.events());
        let r = replay(sink.events());
        assert_eq!(r.metrics.devices[0].hedged, 1);
        assert_eq!(r.metrics.devices[0].cancelled, 1);
        assert_eq!(r.metrics.devices[1].cancelled, 0);
        // The resubmitted victim is interrupted but neither migrated
        // nor lost — the retry tier owns it now.
        assert_eq!(r.metrics.devices[1].interrupted, 1);
        assert_eq!(r.metrics.devices[1].lost, 0);
        assert_eq!(r.metrics.devices[1].migrated, 0);
        let c0 = r.metrics.classes.iter().find(|c| c.class == 0).expect("class 0");
        assert_eq!(c0.retries, 1);
        let c1 = r.metrics.classes.iter().find(|c| c.class == 1).expect("class 1");
        assert_eq!((c1.retries, c1.degraded, c1.interrupted), (1, 1, 1));
        // (Live cores emit Shed xor Retry for one failure — this
        // fixture pairs them only to exercise both folds at once.)
        assert_eq!(r.metrics.rejected, 1);
    }

    #[test]
    fn parse_rejects_garbage_and_unknown_kinds() {
        assert!(parse_jsonl("not json\n").is_err());
        assert!(parse_jsonl("{\"ev\":\"warp\",\"t\":0,\"id\":1,\"class\":0}\n").is_err());
        assert!(parse_jsonl("{\"t\":0,\"id\":1,\"class\":0}\n").is_err());
        // Blank lines are fine.
        assert_eq!(parse_jsonl("\n\n").unwrap(), Vec::new());
    }

    /// Regression: an unknown event kind must be a loud `Err` naming
    /// the kind and the 1-based line number — a replayer that predates
    /// a trace's event vocabulary must refuse the file, not silently
    /// drop lines. The bad line here is a plausible future fault kind.
    #[test]
    fn unknown_kinds_fail_loudly_with_kind_and_line_number() {
        let doc = concat!(
            "{\"ev\":\"admit\",\"t\":0,\"id\":1,\"class\":0}\n",
            "{\"ev\":\"brownout\",\"t\":1,\"dev\":3}\n",
        );
        let err = parse_jsonl(doc).expect_err("unknown kind must not parse");
        assert!(err.contains("trace line 2"), "missing line number: {err}");
        assert!(err.contains("unknown event kind 'brownout'"), "missing kind: {err}");
        // Same contract for an unknown *fault* sub-kind.
        let doc = "{\"ev\":\"fault\",\"t\":0,\"dev\":1,\"kind\":\"meltdown\"}\n";
        let err = parse_jsonl(doc).expect_err("unknown fault kind must not parse");
        assert!(err.contains("trace line 1"), "missing line number: {err}");
        assert!(err.contains("unknown fault kind 'meltdown'"), "missing kind: {err}");
    }

    #[test]
    fn churn_events_round_trip_jsonl() {
        let mut sink = TraceSink::new();
        for ev in [
            TraceEvent::Fault { t: 0.5, device: 2, fault: TraceFault::Crash },
            TraceEvent::Fault { t: 0.75, device: 1, fault: TraceFault::Outage { until_s: 0.9 } },
            TraceEvent::Fault { t: 1.0, device: 0, fault: TraceFault::Slow { factor: 1.5 } },
            TraceEvent::Recover { t: 0.9, device: 1 },
            TraceEvent::Migrate { t: 0.5, id: 4, class: 1, from: 2, to: 0, resident: true },
            TraceEvent::Migrate { t: 0.5, id: 5, class: 0, from: 2, to: -1, resident: false },
            TraceEvent::Migrate { t: 0.5, id: 6, class: 2, from: 2, to: -2, resident: true },
        ] {
            sink.record(ev);
        }
        let text = sink.to_jsonl();
        // Churn events carry no request id/class (line 1 is the header).
        for line in text.lines().skip(1).take(4) {
            assert!(!line.contains("\"id\""), "churn line leaked an id: {line}");
        }
        assert_eq!(parse_jsonl(&text).expect("parse"), sink.events());
    }

    #[test]
    fn replay_reconstructs_churn_accounting() {
        let events = [
            TraceEvent::Admit { t: 0.0, id: 1, class: 0 },
            TraceEvent::Route { t: 0.0, id: 1, class: 0, device: 2, est_s: 0.25 },
            // Device 1: outage from t=1 to t=2 (downtime 1.0).
            TraceEvent::Fault { t: 1.0, device: 1, fault: TraceFault::Outage { until_s: 2.0 } },
            TraceEvent::Recover { t: 2.0, device: 1 },
            // Device 2: crash at t=3, down through the last finish at
            // t=5 (downtime 2.0). Its two victims: one in-flight
            // sample migrated, one queued request lost.
            TraceEvent::Fault { t: 3.0, device: 2, fault: TraceFault::Crash },
            TraceEvent::Migrate { t: 3.0, id: 1, class: 0, from: 2, to: 0, resident: true },
            TraceEvent::Migrate { t: 3.0, id: 9, class: 1, from: 2, to: -2, resident: false },
            TraceEvent::Complete {
                t: 5.0,
                id: 1,
                class: 0,
                device: 0,
                latency_s: 5.0,
                queue_s: 0.5,
                deadline_met: None,
            },
        ];
        let r = replay(&events);
        assert_eq!(r.metrics.devices[1].downtime_s, 1.0);
        assert_eq!(r.metrics.devices[2].downtime_s, 2.0);
        assert_eq!(r.metrics.devices[0].downtime_s, 0.0);
        assert_eq!(r.metrics.devices[2].interrupted, 1);
        assert_eq!(r.metrics.devices[2].migrated, 1);
        assert_eq!(r.metrics.devices[2].lost, 1);
        assert_eq!(r.metrics.devices[2].retried, 0);
        let c0 = r.metrics.classes.iter().find(|c| c.class == 0).expect("class 0");
        assert_eq!((c0.interrupted, c0.migrated), (1, 1));
        let c1 = r.metrics.classes.iter().find(|c| c.class == 1).expect("class 1");
        assert_eq!((c1.lost, c1.interrupted), (1, 0));
        // Churn events never move the makespan: admit t=0 → finish t=5.
        assert_eq!(r.metrics.makespan_s, 5.0);
    }

    #[test]
    fn sentinel_shed_replays_into_unattributed_bucket() {
        // A total-outage shed carries dev=-1: no per-device charge, no
        // panic, counted fleet-wide.
        let events = [
            TraceEvent::Admit { t: 0.0, id: 1, class: 0 },
            TraceEvent::Shed { t: 0.0, id: 1, class: 0, device: -1, tracked: true },
            TraceEvent::Shed { t: 0.1, id: 2, class: 0, device: 0, tracked: false },
        ];
        let r = replay(&events);
        assert_eq!(r.metrics.rejected, 2);
        assert_eq!(r.metrics.shed_unattributed, 1);
        assert_eq!(r.metrics.devices[0].shed, 1);
        let text: String = events.iter().map(|e| e.to_json().to_string_compact() + "\n").collect();
        assert_eq!(parse_jsonl(&text).expect("parse"), events);
    }

    #[test]
    fn replay_rebuilds_counts_and_distributions() {
        let r = replay(&sample_events());
        assert_eq!(r.metrics.samples_completed, 2);
        assert_eq!(r.metrics.rejected, 1);
        // Makespan: first admit at t=0, last complete at t=2.5.
        assert_eq!(r.metrics.makespan_s, 2.5);
        // Both completions had latency 2.0 exactly.
        assert_eq!(r.metrics.latency.count(), 2);
        assert_eq!(r.metrics.latency_p50_s(), 2.0);
        // Admission estimate went to device 0; shed to device 1.
        assert_eq!(r.route_counts, vec![1, 0]);
        assert_eq!(r.metrics.devices[0].admission_est.count(), 1);
        assert_eq!(r.metrics.devices[1].shed, 1);
        assert_eq!(r.metrics.devices[0].samples_completed, 1);
        // Class roll-ups: class 2's shed was deadline-tracked.
        let c2 = r.metrics.classes.iter().find(|c| c.class == 2).expect("class 2");
        assert_eq!((c2.shed, c2.shed_tracked), (1, 1));
    }

    #[test]
    fn replay_of_empty_trace_is_all_zeros() {
        let r = replay(&[]);
        assert_eq!(r.metrics.samples_completed, 0);
        assert_eq!(r.metrics.makespan_s, 0.0);
        assert_eq!(r.metrics.latency_p50_s(), 0.0);
        assert!(r.route_counts.is_empty());
    }

    #[test]
    fn zero_step_complete_without_device_replays() {
        // device = -1 (completed at admission): fleet-wide histograms
        // record it; no per-device attribution.
        let events = [
            TraceEvent::Admit { t: 1.0, id: 7, class: 0 },
            TraceEvent::Complete {
                t: 1.0,
                id: 7,
                class: 0,
                device: -1,
                latency_s: 0.0,
                queue_s: 0.0,
                deadline_met: None,
            },
        ];
        let r = replay(&events);
        assert_eq!(r.metrics.samples_completed, 1);
        assert_eq!(r.metrics.latency_p50_s(), 0.0);
        assert_eq!(r.metrics.makespan_s, 0.0);
        assert!(r.metrics.devices.is_empty());
    }

    #[test]
    fn diff_reports_first_divergence_and_route_deltas() {
        let a = sample_events();
        let mut b = a.clone();
        assert!(diff(&a, &b).identical());
        // Change one routing decision.
        b[1] = TraceEvent::Route { t: 0.0, id: 1, class: 0, device: 1, est_s: 0.25 };
        let d = diff(&a, &b);
        let (idx, la, lb) = d.first_divergence.expect("diverged");
        assert_eq!(idx, 1);
        assert!(la.contains("\"dev\":0") && lb.contains("\"dev\":1"));
        // Device 0 lost a route, device 1 gained one.
        assert_eq!(d.route_deltas, vec![(0, 1, 0), (1, 0, 1)]);
        // A truncated trace diverges at the missing tail.
        let shorter = &a[..a.len() - 1];
        let d = diff(&a, shorter);
        let (idx, _, lb) = d.first_divergence.expect("diverged");
        assert_eq!(idx, a.len() - 1);
        assert_eq!(lb, "<end of trace>");
    }

    #[test]
    fn replay_matches_check_against_its_own_summary() {
        let r = replay(&sample_events());
        let report = replay_summary(&r);
        assert!(check_against_report(&r, &report).is_empty());
        let tampered = report.set("latency_p99_s", 123.0);
        assert_eq!(check_against_report(&r, &tampered), vec!["latency_p99_s".to_string()]);
    }
}
